//! Batch normalization (Ioffe & Szegedy), the FP module the paper
//! optionally integrates into Boolean models ("B⊕LD with BN", Table 2).
//! Full training backward; running stats for eval.

use super::{Layer, LayerDesc, ParamRef, ParamStore, Value};
use crate::tensor::Tensor;

/// The ε of every BatchNorm in the repo. Public because the serving-side
/// BN fold (`runtime::graph`) must replay eval-mode BN with the *exact*
/// same constant to stay bit-identical to the training stack.
pub const BN_EPS: f32 = 1e-5;

/// Shared BN core operating on a (rows × features) view, where `rows`
/// aggregates every dimension that is normalized over. Parameter
/// gradients go to the [`ParamStore`] under `<name>.gamma`/`<name>.beta`.
struct BnCore {
    name: String,
    features: usize,
    gamma: Tensor,
    beta: Tensor,
    running_mean: Vec<f32>,
    running_var: Vec<f32>,
    momentum: f32,
    eps: f32,
    // caches
    xhat: Option<Tensor>,
    inv_std: Option<Vec<f32>>,
}

impl BnCore {
    /// Store/buffer key: `<layer name>.<suffix>` — the one place the key
    /// is built (backward, params and buffers all go through it).
    fn key(&self, suffix: &str) -> String {
        format!("{}.{}", self.name, suffix)
    }

    fn new(name: &str, features: usize) -> Self {
        BnCore {
            name: name.to_string(),
            features,
            gamma: Tensor::full(&[features], 1.0),
            beta: Tensor::zeros(&[features]),
            running_mean: vec![0.0; features],
            running_var: vec![1.0; features],
            momentum: 0.1,
            eps: BN_EPS,
            xhat: None,
            inv_std: None,
        }
    }

    /// x is (rows × features); returns normalized output.
    fn forward(&mut self, x: &Tensor, train: bool) -> Tensor {
        let (r, f) = (x.rows(), x.cols());
        assert_eq!(f, self.features);
        let mut out = Tensor::zeros(&[r, f]);
        if train {
            let mut mean = vec![0.0f32; f];
            let mut var = vec![0.0f32; f];
            for i in 0..r {
                for j in 0..f {
                    mean[j] += x.at2(i, j);
                }
            }
            for m in mean.iter_mut() {
                *m /= r as f32;
            }
            for i in 0..r {
                for j in 0..f {
                    let d = x.at2(i, j) - mean[j];
                    var[j] += d * d;
                }
            }
            for v in var.iter_mut() {
                *v /= r as f32;
            }
            let inv_std: Vec<f32> = var.iter().map(|&v| 1.0 / (v + self.eps).sqrt()).collect();
            let mut xhat = Tensor::zeros(&[r, f]);
            for i in 0..r {
                for j in 0..f {
                    let h = (x.at2(i, j) - mean[j]) * inv_std[j];
                    *xhat.at2_mut(i, j) = h;
                    *out.at2_mut(i, j) = self.gamma.data[j] * h + self.beta.data[j];
                }
            }
            for j in 0..f {
                self.running_mean[j] =
                    (1.0 - self.momentum) * self.running_mean[j] + self.momentum * mean[j];
                self.running_var[j] =
                    (1.0 - self.momentum) * self.running_var[j] + self.momentum * var[j];
            }
            self.xhat = Some(xhat);
            self.inv_std = Some(inv_std);
        } else {
            for i in 0..r {
                for j in 0..f {
                    let h = (x.at2(i, j) - self.running_mean[j])
                        / (self.running_var[j] + self.eps).sqrt();
                    *out.at2_mut(i, j) = self.gamma.data[j] * h + self.beta.data[j];
                }
            }
        }
        out
    }

    /// Standard BN backward over the (rows × features) view.
    fn backward(&mut self, z: &Tensor, store: &mut ParamStore) -> Tensor {
        let xhat = self.xhat.as_ref().expect("backward before forward");
        let inv_std = self.inv_std.as_ref().unwrap();
        let (r, f) = (z.rows(), z.cols());
        let rn = r as f32;
        let mut sum_z = vec![0.0f32; f];
        let mut sum_zh = vec![0.0f32; f];
        for i in 0..r {
            for j in 0..f {
                sum_z[j] += z.at2(i, j);
                sum_zh[j] += z.at2(i, j) * xhat.at2(i, j);
            }
        }
        store.accumulate(&self.key("beta"), &Tensor::from_vec(&[f], sum_z.clone()));
        store.accumulate(&self.key("gamma"), &Tensor::from_vec(&[f], sum_zh.clone()));
        let mut gx = Tensor::zeros(&[r, f]);
        for i in 0..r {
            for j in 0..f {
                let zv = z.at2(i, j);
                let g = self.gamma.data[j] * inv_std[j];
                *gx.at2_mut(i, j) =
                    g * (zv - sum_z[j] / rn - xhat.at2(i, j) * sum_zh[j] / rn);
            }
        }
        gx
    }

    fn params(&mut self) -> Vec<ParamRef<'_>> {
        let (gk, bk) = (self.key("gamma"), self.key("beta"));
        vec![
            ParamRef::Real { name: gk, w: &mut self.gamma },
            ParamRef::Real { name: bk, w: &mut self.beta },
        ]
    }

    fn buffers(&mut self) -> Vec<(String, &mut Vec<f32>)> {
        let (mk, vk) = (self.key("running_mean"), self.key("running_var"));
        vec![(mk, &mut self.running_mean), (vk, &mut self.running_var)]
    }
}

/// BatchNorm over the feature dimension of a (batch × features) tensor.
pub struct BatchNorm1d {
    core: BnCore,
    name: String,
}

impl BatchNorm1d {
    pub fn new(name: &str, features: usize) -> Self {
        BatchNorm1d { core: BnCore::new(name, features), name: name.to_string() }
    }
}

impl Layer for BatchNorm1d {
    fn forward(&mut self, x: Value, train: bool) -> Value {
        let t = x.to_f32();
        Value::F32(self.core.forward(&t, train))
    }

    fn backward(&mut self, z: Tensor, store: &mut ParamStore) -> Tensor {
        self.core.backward(&z, store)
    }

    fn params(&mut self) -> Vec<ParamRef<'_>> {
        self.core.params()
    }

    fn buffers(&mut self) -> Vec<(String, &mut Vec<f32>)> {
        self.core.buffers()
    }

    fn name(&self) -> String {
        self.name.clone()
    }

    fn describe(&self) -> Option<Vec<LayerDesc>> {
        Some(vec![LayerDesc::BatchNorm1d {
            name: self.name.clone(),
            features: self.core.features,
        }])
    }
}

/// BatchNorm over channels of an NCHW tensor (stats over N·H·W).
pub struct BatchNorm2d {
    core: BnCore,
    name: String,
    cache_dims: Option<(usize, usize, usize, usize)>,
}

impl BatchNorm2d {
    pub fn new(name: &str, channels: usize) -> Self {
        BatchNorm2d { core: BnCore::new(name, channels), name: name.to_string(), cache_dims: None }
    }
}

impl Layer for BatchNorm2d {
    fn forward(&mut self, x: Value, train: bool) -> Value {
        let t = x.to_f32();
        let (n, c, h, w) = t.dims4();
        self.cache_dims = Some((n, c, h, w));
        let rows = t.nchw_to_rows(); // (N·H·W × C)
        let out = self.core.forward(&rows, train);
        Value::F32(out.rows_to_nchw(n, c, h, w))
    }

    fn backward(&mut self, z: Tensor, store: &mut ParamStore) -> Tensor {
        let (n, c, h, w) = self.cache_dims.expect("backward before forward");
        let gz = self.core.backward(&z.nchw_to_rows(), store);
        gz.rows_to_nchw(n, c, h, w)
    }

    fn params(&mut self) -> Vec<ParamRef<'_>> {
        self.core.params()
    }

    fn buffers(&mut self) -> Vec<(String, &mut Vec<f32>)> {
        self.core.buffers()
    }

    fn name(&self) -> String {
        self.name.clone()
    }

    fn describe(&self) -> Option<Vec<LayerDesc>> {
        Some(vec![LayerDesc::BatchNorm2d {
            name: self.name.clone(),
            features: self.core.features,
        }])
    }
}

/// Layer normalization (per-row over the last dim) — the transformer
/// norm used by the Boolean BERT model (Table 7). FP, trained with Adam.
pub struct LayerNorm {
    pub features: usize,
    pub gamma: Tensor,
    pub beta: Tensor,
    eps: f32,
    name: String,
    cache: Option<(Tensor, Vec<f32>)>, // (xhat, inv_std per row)
}

impl LayerNorm {
    pub fn new(name: &str, features: usize) -> Self {
        LayerNorm {
            features,
            gamma: Tensor::full(&[features], 1.0),
            beta: Tensor::zeros(&[features]),
            eps: 1e-5,
            name: name.to_string(),
            cache: None,
        }
    }

    /// Store key: `<layer name>.<suffix>` (single source of truth).
    fn key(&self, suffix: &str) -> String {
        format!("{}.{}", self.name, suffix)
    }

    /// Forward on a (rows × features) tensor.
    pub fn fwd(&mut self, x: &Tensor, train: bool) -> Tensor {
        let (r, f) = (x.rows(), x.cols());
        assert_eq!(f, self.features);
        let mut out = Tensor::zeros(&[r, f]);
        let mut xhat = Tensor::zeros(&[r, f]);
        let mut inv_stds = vec![0.0f32; r];
        for i in 0..r {
            let row = &x.data[i * f..(i + 1) * f];
            let mean: f32 = row.iter().sum::<f32>() / f as f32;
            let var: f32 = row.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / f as f32;
            let inv = 1.0 / (var + self.eps).sqrt();
            inv_stds[i] = inv;
            for j in 0..f {
                let h = (row[j] - mean) * inv;
                *xhat.at2_mut(i, j) = h;
                *out.at2_mut(i, j) = self.gamma.data[j] * h + self.beta.data[j];
            }
        }
        if train {
            self.cache = Some((xhat, inv_stds));
        }
        out
    }

    /// Backward on a (rows × features) signal.
    pub fn bwd(&mut self, z: &Tensor, store: &mut ParamStore) -> Tensor {
        let (xhat, inv_stds) = self.cache.as_ref().expect("backward before forward");
        let (r, f) = (z.rows(), z.cols());
        let fn_ = f as f32;
        let mut gx = Tensor::zeros(&[r, f]);
        let mut g_beta = vec![0.0f32; f];
        let mut g_gamma = vec![0.0f32; f];
        for i in 0..r {
            let mut sum_z = 0.0f32;
            let mut sum_zh = 0.0f32;
            for j in 0..f {
                let zg = z.at2(i, j) * self.gamma.data[j];
                sum_z += zg;
                sum_zh += zg * xhat.at2(i, j);
                g_beta[j] += z.at2(i, j);
                g_gamma[j] += z.at2(i, j) * xhat.at2(i, j);
            }
            for j in 0..f {
                let zg = z.at2(i, j) * self.gamma.data[j];
                *gx.at2_mut(i, j) =
                    inv_stds[i] * (zg - sum_z / fn_ - xhat.at2(i, j) * sum_zh / fn_);
            }
        }
        store.accumulate(&self.key("beta"), &Tensor::from_vec(&[f], g_beta));
        store.accumulate(&self.key("gamma"), &Tensor::from_vec(&[f], g_gamma));
        gx
    }

    pub fn params(&mut self) -> Vec<ParamRef<'_>> {
        let (gk, bk) = (self.key("gamma"), self.key("beta"));
        vec![
            ParamRef::Real { name: gk, w: &mut self.gamma },
            ParamRef::Real { name: bk, w: &mut self.beta },
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn layernorm_normalizes_rows() {
        let mut rng = Rng::new(9);
        let mut ln = LayerNorm::new("ln", 16);
        let x = Tensor::randn(&[4, 16], 3.0, &mut rng).map(|v| v + 5.0);
        let y = ln.fwd(&x, true);
        for i in 0..4 {
            let row = &y.data[i * 16..(i + 1) * 16];
            let mean: f32 = row.iter().sum::<f32>() / 16.0;
            let var: f32 = row.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / 16.0;
            assert!(mean.abs() < 1e-4);
            assert!((var - 1.0).abs() < 1e-2);
        }
    }

    #[test]
    fn layernorm_backward_fd() {
        let mut rng = Rng::new(10);
        let mut ln = LayerNorm::new("ln", 5);
        let mut store = ParamStore::new();
        let x = Tensor::randn(&[3, 5], 1.0, &mut rng);
        let y = ln.fwd(&x, true);
        let gx = ln.bwd(&y, &mut store); // L = ||y||²/2
        let eps = 1e-3;
        let loss = |ln: &mut LayerNorm, x: &Tensor| -> f32 {
            let y = ln.fwd(x, true);
            0.5 * y.data.iter().map(|v| v * v).sum::<f32>()
        };
        for idx in [0usize, 7, 12] {
            let mut x2 = x.clone();
            x2.data[idx] += eps;
            let lp = loss(&mut ln, &x2);
            x2.data[idx] -= 2.0 * eps;
            let lm = loss(&mut ln, &x2);
            let num = (lp - lm) / (2.0 * eps);
            assert!((num - gx.data[idx]).abs() < 0.05 * num.abs().max(0.5),
                "idx {idx}: {num} vs {}", gx.data[idx]);
        }
    }

    #[test]
    fn train_output_is_normalized() {
        let mut rng = Rng::new(1);
        let mut bn = BatchNorm1d::new("bn", 5);
        let x = Tensor::randn(&[64, 5], 3.0, &mut rng).map(|v| v + 7.0);
        let y = bn.forward(Value::F32(x), true).expect_f32("t");
        for j in 0..5 {
            let col: Vec<f32> = (0..64).map(|i| y.at2(i, j)).collect();
            let mean = col.iter().sum::<f32>() / 64.0;
            let var = col.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / 64.0;
            assert!(mean.abs() < 1e-4, "mean {mean}");
            assert!((var - 1.0).abs() < 1e-2, "var {var}");
        }
    }

    #[test]
    fn backward_matches_finite_difference() {
        let mut rng = Rng::new(2);
        let mut bn = BatchNorm1d::new("bn", 3);
        let mut store = ParamStore::new();
        let x = Tensor::randn(&[8, 3], 1.0, &mut rng);
        let y = bn.forward(Value::F32(x.clone()), true).expect_f32("t");
        let gx = bn.backward(y.clone(), &mut store); // L = ||y||²/2
        let eps = 1e-3;
        let loss = |bn: &mut BatchNorm1d, x: &Tensor| -> f32 {
            let y = bn.forward(Value::F32(x.clone()), true).expect_f32("t");
            0.5 * y.data.iter().map(|v| v * v).sum::<f32>()
        };
        for idx in [0usize, 7, 13] {
            let mut x2 = x.clone();
            x2.data[idx] += eps;
            let lp = loss(&mut bn, &x2);
            x2.data[idx] -= 2.0 * eps;
            let lm = loss(&mut bn, &x2);
            let num = (lp - lm) / (2.0 * eps);
            assert!(
                (num - gx.data[idx]).abs() < 0.05 * num.abs().max(0.5),
                "idx {idx}: fd {num} vs {}", gx.data[idx]
            );
        }
    }

    #[test]
    fn eval_uses_running_stats() {
        let mut rng = Rng::new(3);
        let mut bn = BatchNorm1d::new("bn", 2);
        // train several batches to populate running stats
        for _ in 0..50 {
            let x = Tensor::randn(&[32, 2], 2.0, &mut rng).map(|v| v + 1.0);
            let _ = bn.forward(Value::F32(x), true);
        }
        // eval on a constant input: output should be ~(const-1)/2 scaled
        let x = Tensor::full(&[4, 2], 1.0);
        let y = bn.forward(Value::F32(x), false).expect_f32("t");
        for &v in &y.data {
            assert!(v.abs() < 0.2, "running mean should center ~1.0: {v}");
        }
    }

    #[test]
    fn bn2d_normalizes_per_channel() {
        let mut rng = Rng::new(4);
        let mut bn = BatchNorm2d::new("bn2", 3);
        let x = Tensor::randn(&[4, 3, 5, 5], 2.0, &mut rng).map(|v| v - 3.0);
        let y = bn.forward(Value::F32(x), true).expect_f32("t");
        let (n, c, h, w) = y.dims4();
        for ci in 0..c {
            let mut vals = Vec::new();
            for ni in 0..n {
                for p in 0..h * w {
                    vals.push(y.data[((ni * c + ci) * h * w) + p]);
                }
            }
            let mean = vals.iter().sum::<f32>() / vals.len() as f32;
            assert!(mean.abs() < 1e-4);
        }
    }
}
