//! Dense FP 2-D convolution (im2col + GEMM) with full backward — the
//! substrate for FP baselines and the BNN baselines' latent-weight path.

use super::{Layer, LayerDesc, ParamRef, ParamStore, Value};
use crate::tensor::Tensor;
use crate::util::Rng;

/// FP Conv2d (NCHW, square kernel). Weights stored (c_out × c_in·k·k);
/// gradients accumulate in the [`ParamStore`] under `<name>.w`/`<name>.b`.
pub struct Conv2d {
    pub c_in: usize,
    pub c_out: usize,
    pub k: usize,
    pub stride: usize,
    pub pad: usize,
    pub w: Tensor,
    pub b: Tensor,
    name: String,
    cache_cols: Option<Tensor>,
    cache_dims: Option<(usize, usize, usize, usize, usize)>,
}

impl Conv2d {
    pub fn new(
        name: &str,
        c_in: usize,
        c_out: usize,
        k: usize,
        stride: usize,
        pad: usize,
        rng: &mut Rng,
    ) -> Self {
        let fanin = c_in * k * k;
        let std = (2.0 / fanin as f32).sqrt();
        Conv2d {
            c_in,
            c_out,
            k,
            stride,
            pad,
            w: Tensor::randn(&[c_out, fanin], std, rng),
            b: Tensor::zeros(&[c_out]),
            name: name.to_string(),
            cache_cols: None,
            cache_dims: None,
        }
    }

    pub fn out_hw(&self, h: usize, w: usize) -> (usize, usize) {
        (
            (h + 2 * self.pad - self.k) / self.stride + 1,
            (w + 2 * self.pad - self.k) / self.stride + 1,
        )
    }

    /// Store key of the weight parameter.
    pub fn w_key(&self) -> String {
        format!("{}.w", self.name)
    }

    /// Store key of the bias parameter.
    pub fn b_key(&self) -> String {
        format!("{}.b", self.name)
    }
}

impl Layer for Conv2d {
    fn forward(&mut self, x: Value, train: bool) -> Value {
        let t = x.to_f32();
        let (n, c, h, w) = t.dims4();
        assert_eq!(c, self.c_in, "{}: channels", self.name);
        let (oh, ow) = self.out_hw(h, w);
        let cols = t.im2col(self.k, self.stride, self.pad);
        let mut y_rows = cols.matmul_bt(&self.w); // (N·OH·OW × Cout)
        for i in 0..y_rows.rows() {
            for j in 0..self.c_out {
                *y_rows.at2_mut(i, j) += self.b.data[j];
            }
        }
        let y = y_rows.rows_to_nchw(n, self.c_out, oh, ow);
        if train {
            self.cache_cols = Some(cols);
            self.cache_dims = Some((n, h, w, oh, ow));
        }
        Value::F32(y)
    }

    fn backward(&mut self, z: Tensor, store: &mut ParamStore) -> Tensor {
        let (n, h, w, oh, ow) = self.cache_dims.expect("backward before forward");
        assert_eq!(z.shape, vec![n, self.c_out, oh, ow]);
        let z_rows = z.nchw_to_rows();
        let cols = self.cache_cols.as_ref().unwrap();
        store.accumulate(&self.w_key(), &z_rows.matmul_at(cols));
        store.accumulate(&self.b_key(), &z_rows.sum_rows());
        let g_cols = z_rows.matmul(&self.w);
        g_cols.col2im(n, self.c_in, h, w, self.k, self.stride, self.pad)
    }

    fn params(&mut self) -> Vec<ParamRef<'_>> {
        let (wk, bk) = (self.w_key(), self.b_key());
        vec![
            ParamRef::Real { name: wk, w: &mut self.w },
            ParamRef::Real { name: bk, w: &mut self.b },
        ]
    }

    fn name(&self) -> String {
        self.name.clone()
    }

    fn describe(&self) -> Option<Vec<LayerDesc>> {
        Some(vec![LayerDesc::Conv2d {
            name: self.name.clone(),
            c_in: self.c_in,
            c_out: self.c_out,
            k: self.k,
            stride: self.stride,
            pad: self.pad,
        }])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identity_kernel_passthrough() {
        // 1x1 conv with identity-ish weights reproduces the input channel.
        let mut rng = Rng::new(1);
        let mut conv = Conv2d::new("c", 2, 2, 1, 1, 0, &mut rng);
        conv.w = Tensor::from_vec(&[2, 2], vec![1.0, 0.0, 0.0, 1.0]);
        conv.b = Tensor::zeros(&[2]);
        let x = Tensor::randn(&[1, 2, 3, 3], 1.0, &mut rng);
        let y = conv.forward(Value::F32(x.clone()), false).expect_f32("t");
        assert!(y.max_abs_diff(&x) < 1e-6);
    }

    #[test]
    fn gradient_matches_finite_difference() {
        let mut rng = Rng::new(2);
        let mut conv = Conv2d::new("c", 2, 3, 3, 1, 1, &mut rng);
        let mut store = ParamStore::new();
        let x = Tensor::randn(&[2, 2, 4, 4], 1.0, &mut rng);
        let y = conv.forward(Value::F32(x.clone()), true).expect_f32("t");
        let gx = conv.backward(y.clone(), &mut store); // L = ||y||²/2
        let gw = store.grad("c.w").unwrap().clone();
        let eps = 1e-3;
        let loss = |c: &mut Conv2d, x: &Tensor| -> f32 {
            let y = c.forward(Value::F32(x.clone()), false).expect_f32("t");
            0.5 * y.data.iter().map(|v| v * v).sum::<f32>()
        };
        for &(i, j) in &[(0usize, 0usize), (2, 17), (1, 9)] {
            let orig = conv.w.at2(i, j);
            *conv.w.at2_mut(i, j) = orig + eps;
            let lp = loss(&mut conv, &x);
            *conv.w.at2_mut(i, j) = orig - eps;
            let lm = loss(&mut conv, &x);
            *conv.w.at2_mut(i, j) = orig;
            let num = (lp - lm) / (2.0 * eps);
            assert!(
                (num - gw.at2(i, j)).abs() < 0.05 * num.abs().max(1.0),
                "w[{i},{j}]: fd {num} vs analytic {}",
                gw.at2(i, j)
            );
        }
        // input gradient spot check
        let idx = 7;
        let mut x2 = x.clone();
        x2.data[idx] += eps;
        let lp = loss(&mut conv, &x2);
        x2.data[idx] -= 2.0 * eps;
        let lm = loss(&mut conv, &x2);
        let num = (lp - lm) / (2.0 * eps);
        assert!((num - gx.data[idx]).abs() < 0.05 * num.abs().max(1.0));
    }

    #[test]
    fn strided_output_shape() {
        let mut rng = Rng::new(3);
        let mut conv = Conv2d::new("c", 3, 8, 3, 2, 1, &mut rng);
        let x = Tensor::zeros(&[2, 3, 8, 8]);
        let y = conv.forward(Value::F32(x), false).expect_f32("t");
        assert_eq!(y.shape, vec![2, 8, 4, 4]);
    }
}
