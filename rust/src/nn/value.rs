//! The value type flowing between layers: dense f32 or packed Boolean.

use crate::tensor::{BitMatrix, Tensor};

/// Forward dataflow value.
#[derive(Debug, Clone)]
pub enum Value {
    /// Dense f32 tensor of arbitrary shape.
    F32(Tensor),
    /// Bit-packed Boolean data. `shape` is the logical shape; the packing
    /// is batch-major: `bits` has `shape[0]` rows and `∏ shape[1..]` cols.
    Bit { bits: BitMatrix, shape: Vec<usize> },
}

impl Value {
    pub fn shape(&self) -> &[usize] {
        match self {
            Value::F32(t) => &t.shape,
            Value::Bit { shape, .. } => shape,
        }
    }

    pub fn batch(&self) -> usize {
        self.shape()[0]
    }

    /// Unpack to a dense ±1 (or original) f32 tensor.
    pub fn to_f32(&self) -> Tensor {
        match self {
            Value::F32(t) => t.clone(),
            Value::Bit { bits, shape } => bits.to_pm1().reshape(shape),
        }
    }

    /// Pack from a ±1 tensor, flattening all non-batch dims.
    pub fn bit_from_pm1(t: &Tensor) -> Value {
        let batch = t.shape[0];
        let cols: usize = t.shape[1..].iter().product();
        let flat = t.view(&[batch, cols]);
        Value::Bit { bits: BitMatrix::from_pm1(&flat), shape: t.shape.clone() }
    }

    pub fn expect_f32(self, who: &str) -> Tensor {
        match self {
            Value::F32(t) => t,
            Value::Bit { .. } => panic!("{who}: expected F32 value, got Bit"),
        }
    }

    pub fn expect_bit(self, who: &str) -> (BitMatrix, Vec<usize>) {
        match self {
            Value::Bit { bits, shape } => (bits, shape),
            Value::F32(_) => panic!("{who}: expected Bit value, got F32"),
        }
    }

    pub fn is_bit(&self) -> bool {
        matches!(self, Value::Bit { .. })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn bit_roundtrip_through_f32() {
        let mut rng = Rng::new(1);
        let t = Tensor::rand_pm1(&[3, 2, 4, 4], &mut rng);
        let v = Value::bit_from_pm1(&t);
        assert_eq!(v.shape(), &[3, 2, 4, 4]);
        assert_eq!(v.to_f32(), t);
    }

    #[test]
    fn f32_passthrough() {
        let t = Tensor::from_vec(&[2, 2], vec![0.5, -1.5, 2.0, 0.0]);
        let v = Value::F32(t.clone());
        assert_eq!(v.to_f32(), t);
        assert_eq!(v.batch(), 2);
    }
}
