//! Losses: softmax cross-entropy (classification/segmentation), MSE and L1
//! (super-resolution, matching the paper's EDSR training, Appendix D.2).

use crate::tensor::Tensor;

/// Loss evaluation result: scalar loss, gradient w.r.t. the prediction,
/// and (for classification) the number of correct top-1 predictions.
pub struct LossOut {
    pub loss: f32,
    pub grad: Tensor,
    pub correct: usize,
}

/// Mean softmax cross-entropy over integer labels.
/// Gradient is (softmax − onehot) / batch.
pub fn softmax_cross_entropy(logits: &Tensor, labels: &[usize]) -> LossOut {
    let (b, c) = (logits.rows(), logits.cols());
    assert_eq!(labels.len(), b);
    let mut grad = Tensor::zeros(&[b, c]);
    let mut loss = 0.0f64;
    let mut correct = 0usize;
    for i in 0..b {
        let row = &logits.data[i * c..(i + 1) * c];
        let mx = row.iter().copied().fold(f32::NEG_INFINITY, f32::max);
        let exps: Vec<f32> = row.iter().map(|&v| (v - mx).exp()).collect();
        let z: f32 = exps.iter().sum();
        let y = labels[i];
        debug_assert!(y < c);
        let p_y = exps[y] / z;
        loss -= (p_y.max(1e-12) as f64).ln();
        let mut best = 0;
        for j in 0..c {
            if row[j] > row[best] {
                best = j;
            }
            let p = exps[j] / z;
            *grad.at2_mut(i, j) = (p - if j == y { 1.0 } else { 0.0 }) / b as f32;
        }
        if best == y {
            correct += 1;
        }
    }
    LossOut { loss: (loss / b as f64) as f32, grad, correct }
}

/// Per-pixel softmax cross-entropy for segmentation: logits NCHW, labels
/// (N·H·W) of class ids; `ignore` skips a label id (e.g. void class).
pub fn softmax_cross_entropy_nchw(
    logits: &Tensor,
    labels: &[usize],
    ignore: Option<usize>,
) -> LossOut {
    let (n, c, h, w) = logits.dims4();
    assert_eq!(labels.len(), n * h * w);
    let rows = logits.nchw_to_rows(); // (N·H·W × C)
    let mut grad_rows = Tensor::zeros(&[n * h * w, c]);
    let mut loss = 0.0f64;
    let mut counted = 0usize;
    let mut correct = 0usize;
    for (i, &y) in labels.iter().enumerate() {
        if Some(y) == ignore {
            continue;
        }
        counted += 1;
        let row = &rows.data[i * c..(i + 1) * c];
        let mx = row.iter().copied().fold(f32::NEG_INFINITY, f32::max);
        let exps: Vec<f32> = row.iter().map(|&v| (v - mx).exp()).collect();
        let z: f32 = exps.iter().sum();
        let p_y = exps[y] / z;
        loss -= (p_y.max(1e-12) as f64).ln();
        let mut best = 0;
        for j in 0..c {
            if row[j] > row[best] {
                best = j;
            }
            *grad_rows.at2_mut(i, j) = exps[j] / z - if j == y { 1.0 } else { 0.0 };
        }
        if best == y {
            correct += 1;
        }
    }
    let denom = counted.max(1) as f32;
    grad_rows.scale_inplace(1.0 / denom);
    LossOut {
        loss: (loss / denom as f64) as f32,
        grad: grad_rows.rows_to_nchw(n, c, h, w),
        correct,
    }
}

/// Mean squared error. Gradient is 2(pred − target)/numel.
pub fn mse_loss(pred: &Tensor, target: &Tensor) -> LossOut {
    assert_eq!(pred.shape, target.shape);
    let n = pred.len() as f32;
    let mut grad = Tensor::zeros(&pred.shape);
    let mut loss = 0.0f64;
    for i in 0..pred.len() {
        let d = pred.data[i] - target.data[i];
        loss += (d * d) as f64;
        grad.data[i] = 2.0 * d / n;
    }
    LossOut { loss: (loss / n as f64) as f32, grad, correct: 0 }
}

/// Mean absolute error (the EDSR training loss). Gradient is sign(d)/numel.
pub fn l1_loss(pred: &Tensor, target: &Tensor) -> LossOut {
    assert_eq!(pred.shape, target.shape);
    let n = pred.len() as f32;
    let mut grad = Tensor::zeros(&pred.shape);
    let mut loss = 0.0f64;
    for i in 0..pred.len() {
        let d = pred.data[i] - target.data[i];
        loss += d.abs() as f64;
        grad.data[i] = d.signum() / n;
    }
    LossOut { loss: (loss / n as f64) as f32, grad, correct: 0 }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn ce_uniform_logits() {
        // uniform logits over C classes ⇒ loss = ln C
        let logits = Tensor::zeros(&[4, 10]);
        let out = softmax_cross_entropy(&logits, &[0, 1, 2, 3]);
        assert!((out.loss - (10.0f32).ln()).abs() < 1e-5);
    }

    #[test]
    fn ce_gradient_matches_finite_difference() {
        let mut rng = Rng::new(1);
        let logits = Tensor::randn(&[3, 5], 1.0, &mut rng);
        let labels = [2usize, 0, 4];
        let out = softmax_cross_entropy(&logits, &labels);
        let eps = 1e-3;
        for idx in [0usize, 7, 14] {
            let mut lp = logits.clone();
            lp.data[idx] += eps;
            let lm = {
                let mut t = logits.clone();
                t.data[idx] -= eps;
                softmax_cross_entropy(&t, &labels).loss
            };
            let num = (softmax_cross_entropy(&lp, &labels).loss - lm) / (2.0 * eps);
            assert!((num - out.grad.data[idx]).abs() < 1e-3, "idx {idx}");
        }
    }

    #[test]
    fn ce_perfect_prediction_low_loss() {
        let mut logits = Tensor::zeros(&[2, 3]);
        *logits.at2_mut(0, 1) = 20.0;
        *logits.at2_mut(1, 2) = 20.0;
        let out = softmax_cross_entropy(&logits, &[1, 2]);
        assert!(out.loss < 1e-4);
        assert_eq!(out.correct, 2);
    }

    #[test]
    fn nchw_ce_with_ignore() {
        let mut rng = Rng::new(2);
        let logits = Tensor::randn(&[1, 3, 2, 2], 1.0, &mut rng);
        let labels = vec![0usize, 1, 255, 2];
        let out = softmax_cross_entropy_nchw(&logits, &labels, Some(255));
        assert!(out.loss.is_finite());
        // ignored pixel has zero gradient in all channels
        for c in 0..3 {
            assert_eq!(out.grad.data[c * 4 + 2], 0.0);
        }
    }

    #[test]
    fn l1_and_mse_basics() {
        let p = Tensor::from_vec(&[1, 4], vec![1.0, 2.0, 3.0, 4.0]);
        let t = Tensor::from_vec(&[1, 4], vec![1.0, 1.0, 5.0, 4.0]);
        let l1 = l1_loss(&p, &t);
        assert!((l1.loss - 0.75).abs() < 1e-6);
        assert_eq!(l1.grad.data[1], 0.25);
        assert_eq!(l1.grad.data[2], -0.25);
        let mse = mse_loss(&p, &t);
        assert!((mse.loss - (1.0 + 4.0) / 4.0).abs() < 1e-6);
    }
}
