//! Pooling layers. MaxPool operates on the integer pre-activations before
//! the threshold activation, matching the paper's Appendix C Eq. (44)
//! pipeline (Conv → MP → tanh'-scaled activation).

use super::{Layer, LayerDesc, ParamStore, Value};
use crate::tensor::Tensor;

/// 2×2 (or k×k) max pooling with stride = k on NCHW f32 tensors.
pub struct MaxPool2d {
    pub k: usize,
    name: String,
    cache_argmax: Option<Vec<usize>>,
    cache_dims: Option<(usize, usize, usize, usize)>,
}

impl MaxPool2d {
    pub fn new(name: &str, k: usize) -> Self {
        MaxPool2d { k, name: name.to_string(), cache_argmax: None, cache_dims: None }
    }
}

impl Layer for MaxPool2d {
    fn forward(&mut self, x: Value, train: bool) -> Value {
        let t = x.to_f32();
        let (n, c, h, w) = t.dims4();
        let k = self.k;
        assert!(h % k == 0 && w % k == 0, "{}: {h}x{w} not divisible by {k}", self.name);
        let (oh, ow) = (h / k, w / k);
        let mut out = Tensor::zeros(&[n, c, oh, ow]);
        let mut argmax = vec![0usize; n * c * oh * ow];
        for ni in 0..n {
            for ci in 0..c {
                let plane = (ni * c + ci) * h * w;
                for oy in 0..oh {
                    for ox in 0..ow {
                        let mut best = f32::NEG_INFINITY;
                        let mut best_idx = 0;
                        for dy in 0..k {
                            for dx in 0..k {
                                let idx = plane + (oy * k + dy) * w + (ox * k + dx);
                                if t.data[idx] > best {
                                    best = t.data[idx];
                                    best_idx = idx;
                                }
                            }
                        }
                        let o = ((ni * c + ci) * oh + oy) * ow + ox;
                        out.data[o] = best;
                        argmax[o] = best_idx;
                    }
                }
            }
        }
        if train {
            self.cache_argmax = Some(argmax);
            self.cache_dims = Some((n, c, h, w));
        }
        Value::F32(out)
    }

    fn backward(&mut self, z: Tensor, _store: &mut ParamStore) -> Tensor {
        let argmax = self.cache_argmax.as_ref().expect("backward before forward");
        let (n, c, h, w) = self.cache_dims.unwrap();
        let mut g = Tensor::zeros(&[n, c, h, w]);
        for (o, &src) in argmax.iter().enumerate() {
            g.data[src] += z.data[o];
        }
        g
    }

    fn name(&self) -> String {
        self.name.clone()
    }

    fn describe(&self) -> Option<Vec<LayerDesc>> {
        Some(vec![LayerDesc::MaxPool2d { name: self.name.clone(), k: self.k }])
    }
}

/// Global average pooling: NCHW → (N, C). Used by the ResNet/DeepLab heads.
pub struct AvgPool2dGlobal {
    name: String,
    cache_dims: Option<(usize, usize, usize, usize)>,
}

impl AvgPool2dGlobal {
    pub fn new(name: &str) -> Self {
        AvgPool2dGlobal { name: name.to_string(), cache_dims: None }
    }
}

impl Layer for AvgPool2dGlobal {
    fn forward(&mut self, x: Value, train: bool) -> Value {
        let t = x.to_f32();
        let (n, c, h, w) = t.dims4();
        if train {
            self.cache_dims = Some((n, c, h, w));
        }
        let mut out = Tensor::zeros(&[n, c]);
        let inv = 1.0 / (h * w) as f32;
        for ni in 0..n {
            for ci in 0..c {
                let plane = (ni * c + ci) * h * w;
                let s: f32 = t.data[plane..plane + h * w].iter().sum();
                *out.at2_mut(ni, ci) = s * inv;
            }
        }
        Value::F32(out)
    }

    fn backward(&mut self, z: Tensor, _store: &mut ParamStore) -> Tensor {
        let (n, c, h, w) = self.cache_dims.expect("backward before forward");
        let inv = 1.0 / (h * w) as f32;
        let mut g = Tensor::zeros(&[n, c, h, w]);
        for ni in 0..n {
            for ci in 0..c {
                let v = z.at2(ni, ci) * inv;
                let plane = (ni * c + ci) * h * w;
                for p in 0..h * w {
                    g.data[plane + p] = v;
                }
            }
        }
        g
    }

    fn name(&self) -> String {
        self.name.clone()
    }

    fn describe(&self) -> Option<Vec<LayerDesc>> {
        Some(vec![LayerDesc::GlobalAvgPool { name: self.name.clone() }])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn maxpool_picks_maxima() {
        let mut p = MaxPool2d::new("mp", 2);
        let x = Tensor::from_vec(
            &[1, 1, 2, 4],
            vec![1.0, 2.0, 5.0, 6.0, 3.0, 4.0, 7.0, 8.0],
        );
        let y = p.forward(Value::F32(x), true).expect_f32("t");
        assert_eq!(y.shape, vec![1, 1, 1, 2]);
        assert_eq!(y.data, vec![4.0, 8.0]);
    }

    #[test]
    fn maxpool_backward_routes_to_argmax() {
        let mut p = MaxPool2d::new("mp", 2);
        let x = Tensor::from_vec(
            &[1, 1, 2, 2],
            vec![1.0, 9.0, 3.0, 4.0],
        );
        let _ = p.forward(Value::F32(x), true);
        let g = p.backward(Tensor::from_vec(&[1, 1, 1, 1], vec![5.0]), &mut ParamStore::new());
        assert_eq!(g.data, vec![0.0, 5.0, 0.0, 0.0]);
    }

    #[test]
    fn maxpool_ties_route_once() {
        // all-equal window: gradient must land exactly once (first index)
        let mut p = MaxPool2d::new("mp", 2);
        let x = Tensor::full(&[1, 1, 2, 2], 1.0);
        let _ = p.forward(Value::F32(x), true);
        let g = p.backward(Tensor::from_vec(&[1, 1, 1, 1], vec![1.0]), &mut ParamStore::new());
        assert_eq!(g.sum(), 1.0);
    }

    #[test]
    fn gap_forward_backward() {
        let mut rng = Rng::new(1);
        let mut p = AvgPool2dGlobal::new("gap");
        let x = Tensor::randn(&[2, 3, 4, 4], 1.0, &mut rng);
        let y = p.forward(Value::F32(x.clone()), true).expect_f32("t");
        assert_eq!(y.shape, vec![2, 3]);
        // mean of plane (0, 1)
        let plane = &x.data[16..32];
        let m = plane.iter().sum::<f32>() / 16.0;
        assert!((y.at2(0, 1) - m).abs() < 1e-5);
        let g = p.backward(Tensor::full(&[2, 3], 16.0), &mut ParamStore::new());
        assert!(g.data.iter().all(|&v| (v - 1.0).abs() < 1e-6));
    }
}
