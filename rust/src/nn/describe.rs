//! Architecture self-description: the compact op list a [`Layer`] emits so
//! the forward-only serving stack can rebuild it from a checkpoint without
//! model-specific code (DESIGN.md §Packed-Graph-Executor).
//!
//! Every servable layer answers [`Layer::describe`] with one
//! [`LayerDesc`] per atomic layer; `Sequential` concatenates its
//! children, `Residual` nests two branch lists. `save_model` serializes
//! the list into a `Record::Arch` checkpoint record (kind 6), and
//! `runtime::PackedGraph::load` compiles it back into packed serving ops.
//! A layer that cannot be described (BERT attention, pixel-shuffle, …)
//! returns `None`, which simply omits the record — such checkpoints still
//! load for training, they are just not graph-servable.
//!
//! [`Layer`]: super::Layer
//! [`Layer::describe`]: super::Layer::describe

use std::io::{self, Read, Write};

/// One atomic layer of a described architecture, with exactly the
/// hyperparameters needed to re-run it forward-only. Parameter tensors are
/// NOT here — they live in the ordinary weight/buffer records of the same
/// checkpoint, keyed by `name`.
#[derive(Debug, Clone, PartialEq)]
pub enum LayerDesc {
    /// Boolean FC: `<name>.weight` (+ `<name>.bias` when `bias`).
    BoolLinear { name: String, n_in: usize, n_out: usize, bias: bool },
    /// FP FC: `<name>.w` / `<name>.b`.
    Linear { name: String, n_in: usize, n_out: usize },
    /// Boolean conv: `<name>.weight` packed (c_out × c_in·k·k).
    BoolConv2d { name: String, c_in: usize, c_out: usize, k: usize, stride: usize, pad: usize },
    /// FP conv: `<name>.w` / `<name>.b`.
    Conv2d { name: String, c_in: usize, c_out: usize, k: usize, stride: usize, pad: usize },
    /// BatchNorm over NCHW channels: `<name>.{gamma,beta}` params,
    /// `<name>.running_{mean,var}` buffers.
    BatchNorm2d { name: String, features: usize },
    /// BatchNorm over flat features.
    BatchNorm1d { name: String, features: usize },
    /// Threshold activation; `centered` adds the `<name>.running_mean`
    /// scalar shift at eval time.
    ThresholdAct { name: String, tau: f32, centered: bool },
    /// k×k max pooling, stride k.
    MaxPool2d { name: String, k: usize },
    /// Global average pooling NCHW → (N, C).
    GlobalAvgPool { name: String },
    /// Flatten to (batch, features).
    Flatten { name: String },
    /// Sign binarization to ±1 bits.
    Binarize { name: String },
    /// FP ReLU (recorded so the graph loader can refuse it by name).
    ReLU { name: String },
    /// Two-branch residual merge on pre-activations.
    Residual { name: String, main: Vec<LayerDesc>, shortcut: Vec<LayerDesc> },
}

impl LayerDesc {
    /// The layer name the desc refers to (record-key prefix).
    pub fn name(&self) -> &str {
        match self {
            LayerDesc::BoolLinear { name, .. }
            | LayerDesc::Linear { name, .. }
            | LayerDesc::BoolConv2d { name, .. }
            | LayerDesc::Conv2d { name, .. }
            | LayerDesc::BatchNorm2d { name, .. }
            | LayerDesc::BatchNorm1d { name, .. }
            | LayerDesc::ThresholdAct { name, .. }
            | LayerDesc::MaxPool2d { name, .. }
            | LayerDesc::GlobalAvgPool { name }
            | LayerDesc::Flatten { name }
            | LayerDesc::Binarize { name }
            | LayerDesc::ReLU { name }
            | LayerDesc::Residual { name, .. } => name,
        }
    }

    /// Human-readable layer kind (error messages, summaries).
    pub fn kind(&self) -> &'static str {
        match self {
            LayerDesc::BoolLinear { .. } => "BoolLinear",
            LayerDesc::Linear { .. } => "Linear",
            LayerDesc::BoolConv2d { .. } => "BoolConv2d",
            LayerDesc::Conv2d { .. } => "Conv2d",
            LayerDesc::BatchNorm2d { .. } => "BatchNorm2d",
            LayerDesc::BatchNorm1d { .. } => "BatchNorm1d",
            LayerDesc::ThresholdAct { .. } => "ThresholdAct",
            LayerDesc::MaxPool2d { .. } => "MaxPool2d",
            LayerDesc::GlobalAvgPool { .. } => "GlobalAvgPool",
            LayerDesc::Flatten { .. } => "Flatten",
            LayerDesc::Binarize { .. } => "Binarize",
            LayerDesc::ReLU { .. } => "ReLU",
            LayerDesc::Residual { .. } => "Residual",
        }
    }

    /// Serialize a desc list (little-endian, recursive for `Residual`):
    /// `u32 len | len × (u8 tag | u32 name_len | name | fields…)`.
    pub fn write_list(w: &mut impl Write, list: &[LayerDesc]) -> io::Result<()> {
        w_u32(w, list.len() as u32)?;
        for d in list {
            d.write_one(w)?;
        }
        Ok(())
    }

    /// Inverse of [`Self::write_list`].
    pub fn read_list(r: &mut impl Read) -> io::Result<Vec<LayerDesc>> {
        let n = r_u32(r)? as usize;
        let mut out = Vec::with_capacity(n.min(1024));
        for _ in 0..n {
            out.push(Self::read_one(r)?);
        }
        Ok(out)
    }

    fn write_one(&self, w: &mut impl Write) -> io::Result<()> {
        match self {
            LayerDesc::BoolLinear { name, n_in, n_out, bias } => {
                w_head(w, 0, name)?;
                w_u32(w, *n_in as u32)?;
                w_u32(w, *n_out as u32)?;
                w.write_all(&[u8::from(*bias)])
            }
            LayerDesc::Linear { name, n_in, n_out } => {
                w_head(w, 1, name)?;
                w_u32(w, *n_in as u32)?;
                w_u32(w, *n_out as u32)
            }
            LayerDesc::BoolConv2d { name, c_in, c_out, k, stride, pad } => {
                w_head(w, 2, name)?;
                w_conv(w, *c_in, *c_out, *k, *stride, *pad)
            }
            LayerDesc::Conv2d { name, c_in, c_out, k, stride, pad } => {
                w_head(w, 3, name)?;
                w_conv(w, *c_in, *c_out, *k, *stride, *pad)
            }
            LayerDesc::BatchNorm2d { name, features } => {
                w_head(w, 4, name)?;
                w_u32(w, *features as u32)
            }
            LayerDesc::BatchNorm1d { name, features } => {
                w_head(w, 5, name)?;
                w_u32(w, *features as u32)
            }
            LayerDesc::ThresholdAct { name, tau, centered } => {
                w_head(w, 6, name)?;
                w.write_all(&tau.to_le_bytes())?;
                w.write_all(&[u8::from(*centered)])
            }
            LayerDesc::MaxPool2d { name, k } => {
                w_head(w, 7, name)?;
                w_u32(w, *k as u32)
            }
            LayerDesc::GlobalAvgPool { name } => w_head(w, 8, name),
            LayerDesc::Flatten { name } => w_head(w, 9, name),
            LayerDesc::Binarize { name } => w_head(w, 10, name),
            LayerDesc::ReLU { name } => w_head(w, 11, name),
            LayerDesc::Residual { name, main, shortcut } => {
                w_head(w, 12, name)?;
                Self::write_list(w, main)?;
                Self::write_list(w, shortcut)
            }
        }
    }

    fn read_one(r: &mut impl Read) -> io::Result<LayerDesc> {
        let mut tag = [0u8; 1];
        r.read_exact(&mut tag)?;
        let name = r_name(r)?;
        Ok(match tag[0] {
            0 => {
                let n_in = r_u32(r)? as usize;
                let n_out = r_u32(r)? as usize;
                let bias = r_u8(r)? != 0;
                LayerDesc::BoolLinear { name, n_in, n_out, bias }
            }
            1 => {
                let n_in = r_u32(r)? as usize;
                let n_out = r_u32(r)? as usize;
                LayerDesc::Linear { name, n_in, n_out }
            }
            2 => {
                let (c_in, c_out, k, stride, pad) = r_conv(r)?;
                LayerDesc::BoolConv2d { name, c_in, c_out, k, stride, pad }
            }
            3 => {
                let (c_in, c_out, k, stride, pad) = r_conv(r)?;
                LayerDesc::Conv2d { name, c_in, c_out, k, stride, pad }
            }
            4 => LayerDesc::BatchNorm2d { name, features: r_u32(r)? as usize },
            5 => LayerDesc::BatchNorm1d { name, features: r_u32(r)? as usize },
            6 => {
                let mut b = [0u8; 4];
                r.read_exact(&mut b)?;
                let tau = f32::from_le_bytes(b);
                let centered = r_u8(r)? != 0;
                LayerDesc::ThresholdAct { name, tau, centered }
            }
            7 => LayerDesc::MaxPool2d { name, k: r_u32(r)? as usize },
            8 => LayerDesc::GlobalAvgPool { name },
            9 => LayerDesc::Flatten { name },
            10 => LayerDesc::Binarize { name },
            11 => LayerDesc::ReLU { name },
            12 => {
                let main = Self::read_list(r)?;
                let shortcut = Self::read_list(r)?;
                LayerDesc::Residual { name, main, shortcut }
            }
            t => {
                return Err(io::Error::new(
                    io::ErrorKind::InvalidData,
                    format!("unknown LayerDesc tag {t}"),
                ))
            }
        })
    }
}

fn w_u32(w: &mut impl Write, v: u32) -> io::Result<()> {
    w.write_all(&v.to_le_bytes())
}

fn r_u32(r: &mut impl Read) -> io::Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn r_u8(r: &mut impl Read) -> io::Result<u8> {
    let mut b = [0u8; 1];
    r.read_exact(&mut b)?;
    Ok(b[0])
}

fn w_head(w: &mut impl Write, tag: u8, name: &str) -> io::Result<()> {
    w.write_all(&[tag])?;
    w_u32(w, name.len() as u32)?;
    w.write_all(name.as_bytes())
}

fn r_name(r: &mut impl Read) -> io::Result<String> {
    let len = r_u32(r)? as usize;
    let mut buf = vec![0u8; len];
    r.read_exact(&mut buf)?;
    String::from_utf8(buf)
        .map_err(|_| io::Error::new(io::ErrorKind::InvalidData, "bad LayerDesc name"))
}

fn w_conv(
    w: &mut impl Write,
    c_in: usize,
    c_out: usize,
    k: usize,
    stride: usize,
    pad: usize,
) -> io::Result<()> {
    for v in [c_in, c_out, k, stride, pad] {
        w_u32(w, v as u32)?;
    }
    Ok(())
}

fn r_conv(r: &mut impl Read) -> io::Result<(usize, usize, usize, usize, usize)> {
    Ok((
        r_u32(r)? as usize,
        r_u32(r)? as usize,
        r_u32(r)? as usize,
        r_u32(r)? as usize,
        r_u32(r)? as usize,
    ))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(list: Vec<LayerDesc>) {
        let mut buf = Vec::new();
        LayerDesc::write_list(&mut buf, &list).unwrap();
        let back = LayerDesc::read_list(&mut buf.as_slice()).unwrap();
        assert_eq!(back, list);
    }

    #[test]
    fn all_variants_roundtrip() {
        roundtrip(vec![
            LayerDesc::Conv2d { name: "stem".into(), c_in: 3, c_out: 16, k: 3, stride: 1, pad: 1 },
            LayerDesc::BatchNorm2d { name: "bn".into(), features: 16 },
            LayerDesc::ThresholdAct { name: "act".into(), tau: 0.25, centered: true },
            LayerDesc::BoolConv2d { name: "bc".into(), c_in: 16, c_out: 32, k: 3, stride: 2, pad: 1 },
            LayerDesc::MaxPool2d { name: "mp".into(), k: 2 },
            LayerDesc::Residual {
                name: "b0".into(),
                main: vec![LayerDesc::ThresholdAct { name: "a1".into(), tau: 0.0, centered: false }],
                shortcut: vec![],
            },
            LayerDesc::GlobalAvgPool { name: "gap".into() },
            LayerDesc::Flatten { name: "fl".into() },
            LayerDesc::Binarize { name: "bin".into() },
            LayerDesc::ReLU { name: "r".into() },
            LayerDesc::BatchNorm1d { name: "bn1".into(), features: 8 },
            LayerDesc::BoolLinear { name: "bl".into(), n_in: 32, n_out: 16, bias: true },
            LayerDesc::Linear { name: "head".into(), n_in: 16, n_out: 10 },
        ]);
    }

    #[test]
    fn empty_list_roundtrips() {
        roundtrip(Vec::new());
    }

    #[test]
    fn bad_tag_rejected() {
        let mut buf = Vec::new();
        w_u32(&mut buf, 1).unwrap();
        buf.push(200); // bogus tag
        w_u32(&mut buf, 0).unwrap();
        assert!(LayerDesc::read_list(&mut buf.as_slice()).is_err());
    }
}
