//! Central parameter store: ONE owner for every piece of trainable state
//! that is not the weight itself.
//!
//! Layers own their weights (packed [`BitMatrix`] bits or FP [`Tensor`]s)
//! and nothing else; everything the optimizers need across steps lives
//! here, keyed by the stable parameter name that [`super::Layer::params`]
//! reports:
//!
//! - the per-step vote/gradient buffer (Eq. 7 aggregation target),
//! - the Boolean accumulator m (Eq. 10) and per-tensor unchanged-ratio
//!   β (Eq. 11) consumed by [`crate::optim::BooleanOptimizer`],
//! - the Adam moments (and shared timestep) for FP parameters.
//!
//! Centralizing state buys three things the per-layer fields could not
//! (DESIGN.md §Parameter-Store): worker vote aggregation is a plain
//! store-to-store add, checkpointing optimizer state for bit-exact resume
//! is one serialization site, and the optimizer step can walk flat slices
//! instead of chasing per-layer references.

use crate::tensor::{BitMatrix, Tensor};
use std::collections::HashMap;

/// Mutable references to a layer's parameters, grouped by kind so the
/// coordinator can route them to the right optimizer (Boolean optimizer
/// for `Bool`, Adam for `Real` — the paper's §4 setup). Weights only:
/// optimizer state lives in the [`ParamStore`] under the same name.
pub enum ParamRef<'a> {
    /// Native Boolean parameter: packed ±1 bits.
    Bool { name: String, bits: &'a mut BitMatrix },
    /// FP parameter.
    Real { name: String, w: &'a mut Tensor },
}

impl ParamRef<'_> {
    pub fn name(&self) -> &str {
        match self {
            ParamRef::Bool { name, .. } => name,
            ParamRef::Real { name, .. } => name,
        }
    }

    pub fn len(&self) -> usize {
        match self {
            ParamRef::Bool { bits, .. } => bits.rows * bits.cols,
            ParamRef::Real { w, .. } => w.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Stable handle for a registered parameter (index into the store's slot
/// table; never invalidated while the store lives).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct ParamId(pub usize);

/// Per-parameter optimizer state. Buffers start empty and are sized on
/// first use, so a store never allocates for parameters that are not
/// trained (e.g. frozen Boolean projections in the ablation runs).
#[derive(Debug, Clone)]
pub struct ParamSlot {
    /// Vote buffer (Boolean params, Eq. 7) / gradient (FP params).
    pub grad: Tensor,
    /// Boolean accumulator m_t (Eq. 10).
    pub accum: Tensor,
    /// Per-tensor unchanged-ratio β_t (Eq. 11); starts at 1.
    pub ratio: f32,
    /// Adam first moment (FP params).
    pub adam_m: Vec<f32>,
    /// Adam second moment (FP params).
    pub adam_v: Vec<f32>,
}

impl ParamSlot {
    fn new() -> Self {
        ParamSlot {
            grad: Tensor::zeros(&[0]),
            accum: Tensor::zeros(&[0]),
            ratio: 1.0,
            adam_m: Vec::new(),
            adam_v: Vec::new(),
        }
    }

    /// Grad buffer shaped like `shape`, allocating zeros on first touch.
    pub fn grad_mut(&mut self, shape: &[usize]) -> &mut Tensor {
        if self.grad.is_empty() {
            self.grad = Tensor::zeros(shape);
        }
        debug_assert_eq!(self.grad.len(), shape.iter().product::<usize>());
        &mut self.grad
    }

    /// Accumulator sized to `len` elements (flat), allocating on first use.
    pub fn accum_mut(&mut self, len: usize) -> &mut Tensor {
        if self.accum.is_empty() && len > 0 {
            self.accum = Tensor::zeros(&[len]);
        }
        assert_eq!(self.accum.len(), len, "accumulator changed size");
        &mut self.accum
    }

    /// Adam moment vectors sized to `len` (allocated zeroed on first use).
    pub fn adam_mut(&mut self, len: usize) -> (&mut Vec<f32>, &mut Vec<f32>) {
        if self.adam_m.is_empty() && len > 0 {
            self.adam_m = vec![0.0; len];
            self.adam_v = vec![0.0; len];
        }
        assert_eq!(self.adam_m.len(), len, "adam state changed size");
        (&mut self.adam_m, &mut self.adam_v)
    }
}

/// The central parameter-state store (see module docs).
///
/// ```
/// use bold::nn::ParamStore;
/// use bold::tensor::Tensor;
///
/// let mut store = ParamStore::new();
/// store.accumulate("fc.w", &Tensor::from_vec(&[1, 2], vec![1.0, 2.0]));
/// store.accumulate("fc.w", &Tensor::from_vec(&[1, 2], vec![1.0, 2.0]));
/// assert_eq!(store.grad("fc.w").unwrap().data, vec![2.0, 4.0]);
/// store.zero_grads();
/// assert_eq!(store.grad("fc.w").unwrap().data, vec![0.0, 0.0]);
/// ```
#[derive(Debug, Clone, Default)]
pub struct ParamStore {
    names: Vec<String>,
    index: HashMap<String, usize>,
    slots: Vec<ParamSlot>,
    /// Shared Adam timestep (bias-correction t); serialized for resume.
    pub adam_t: u64,
}

impl ParamStore {
    pub fn new() -> Self {
        ParamStore { names: Vec::new(), index: HashMap::new(), slots: Vec::new(), adam_t: 0 }
    }

    /// Number of registered parameters.
    pub fn len(&self) -> usize {
        self.slots.len()
    }

    pub fn is_empty(&self) -> bool {
        self.slots.is_empty()
    }

    /// Register `name` (idempotent) and return its stable id.
    pub fn register(&mut self, name: &str) -> ParamId {
        if let Some(&i) = self.index.get(name) {
            return ParamId(i);
        }
        let i = self.slots.len();
        self.names.push(name.to_string());
        self.index.insert(name.to_string(), i);
        self.slots.push(ParamSlot::new());
        ParamId(i)
    }

    /// Name of a registered parameter.
    pub fn name(&self, id: ParamId) -> &str {
        &self.names[id.0]
    }

    /// Registered names, in registration order.
    pub fn names(&self) -> impl Iterator<Item = &str> {
        self.names.iter().map(|s| s.as_str())
    }

    /// Slot by name, if registered.
    pub fn slot(&self, name: &str) -> Option<&ParamSlot> {
        self.index.get(name).map(|&i| &self.slots[i])
    }

    /// Slot by name, registering on first touch.
    pub fn slot_mut(&mut self, name: &str) -> &mut ParamSlot {
        let id = self.register(name);
        &mut self.slots[id.0]
    }

    /// Slot by id.
    pub fn slot_by_id_mut(&mut self, id: ParamId) -> &mut ParamSlot {
        &mut self.slots[id.0]
    }

    /// grad[name] += delta (registering and zero-initializing on first
    /// touch). This is the one call every layer backward makes.
    pub fn accumulate(&mut self, name: &str, delta: &Tensor) {
        let slot = self.slot_mut(name);
        if slot.grad.is_empty() {
            slot.grad = delta.clone();
        } else {
            slot.grad.add_inplace(delta);
        }
    }

    /// The accumulated vote/gradient for `name`, if any.
    pub fn grad(&self, name: &str) -> Option<&Tensor> {
        self.slot(name).filter(|s| !s.grad.is_empty()).map(|s| &s.grad)
    }

    /// Zero every grad buffer (start of a step). Allocations are kept.
    pub fn zero_grads(&mut self) {
        for s in self.slots.iter_mut() {
            s.grad.scale_inplace(0.0);
        }
    }

    /// Vote aggregation (Appendix D.1.1): add every grad buffer of
    /// `other` into this store. Because Eq. 7 votes are additive over
    /// samples, summing worker stores is exactly the big-batch step.
    pub fn add_grads_from(&mut self, other: &ParamStore) {
        for (name, slot) in other.names.iter().zip(&other.slots) {
            if !slot.grad.is_empty() {
                self.accumulate(name, &slot.grad);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn register_is_idempotent_and_stable() {
        let mut s = ParamStore::new();
        let a = s.register("a");
        let b = s.register("b");
        assert_eq!(s.register("a"), a);
        assert_ne!(a, b);
        assert_eq!(s.name(a), "a");
        assert_eq!(s.len(), 2);
    }

    #[test]
    fn accumulate_sums_and_zero_keeps_allocation() {
        let mut s = ParamStore::new();
        let d = Tensor::from_vec(&[2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        s.accumulate("w", &d);
        s.accumulate("w", &d);
        assert_eq!(s.grad("w").unwrap().data, vec![2.0, 4.0, 6.0, 8.0]);
        s.zero_grads();
        // zeroed but still shaped (and `grad()` hides nothing: len > 0)
        assert_eq!(s.grad("w").unwrap().len(), 4);
        assert_eq!(s.grad("w").unwrap().sum(), 0.0);
    }

    #[test]
    fn add_grads_from_is_vote_addition() {
        let mut a = ParamStore::new();
        let mut b = ParamStore::new();
        a.accumulate("w", &Tensor::from_vec(&[2], vec![1.0, -1.0]));
        b.accumulate("w", &Tensor::from_vec(&[2], vec![0.5, 2.0]));
        b.accumulate("only_b", &Tensor::from_vec(&[1], vec![7.0]));
        a.add_grads_from(&b);
        assert_eq!(a.grad("w").unwrap().data, vec![1.5, 1.0]);
        assert_eq!(a.grad("only_b").unwrap().data, vec![7.0]);
    }

    #[test]
    fn slots_lazily_size_their_buffers() {
        let mut s = ParamStore::new();
        let slot = s.slot_mut("w");
        assert!(slot.grad.is_empty());
        slot.accum_mut(8);
        assert_eq!(slot.accum.len(), 8);
        let (m, v) = slot.adam_mut(4);
        assert_eq!(m.len(), 4);
        assert_eq!(v.len(), 4);
        assert_eq!(slot.ratio, 1.0);
    }
}
