//! Central parameter store: ONE owner for every piece of trainable state
//! that is not the weight itself.
//!
//! Layers own their weights (packed [`BitMatrix`] bits or FP [`Tensor`]s)
//! and nothing else; everything the optimizers need across steps lives
//! here, keyed by the stable parameter name that [`super::Layer::params`]
//! reports:
//!
//! - the per-step vote/gradient buffer (Eq. 7 aggregation target),
//! - the Boolean accumulator m (Eq. 10) and per-tensor unchanged-ratio
//!   β (Eq. 11) consumed by [`crate::optim::BooleanOptimizer`],
//! - the Adam moments (and shared timestep) for FP parameters.
//!
//! Centralizing state buys three things the per-layer fields could not
//! (DESIGN.md §Parameter-Store): worker vote aggregation is a plain
//! store-to-store add, checkpointing optimizer state for bit-exact resume
//! is one serialization site, and the optimizer step can walk flat slices
//! instead of chasing per-layer references.

use crate::tensor::{BitMatrix, Tensor};
use std::collections::HashMap;

/// Mutable references to a layer's parameters, grouped by kind so the
/// coordinator can route them to the right optimizer (Boolean optimizer
/// for `Bool`, Adam for `Real` — the paper's §4 setup). Weights only:
/// optimizer state lives in the [`ParamStore`] under the same name.
pub enum ParamRef<'a> {
    /// Native Boolean parameter: packed ±1 bits.
    Bool { name: String, bits: &'a mut BitMatrix },
    /// FP parameter.
    Real { name: String, w: &'a mut Tensor },
}

impl ParamRef<'_> {
    pub fn name(&self) -> &str {
        match self {
            ParamRef::Bool { name, .. } => name,
            ParamRef::Real { name, .. } => name,
        }
    }

    pub fn len(&self) -> usize {
        match self {
            ParamRef::Bool { bits, .. } => bits.rows * bits.cols,
            ParamRef::Real { w, .. } => w.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Stable handle for a registered parameter (index into the store's slot
/// table; never invalidated while the store lives).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct ParamId(pub usize);

/// Per-parameter optimizer state. Buffers start empty and are sized on
/// first use, so a store never allocates for parameters that are not
/// trained (e.g. frozen Boolean projections in the ablation runs).
#[derive(Debug, Clone)]
pub struct ParamSlot {
    /// Vote buffer (Boolean params, Eq. 7) / gradient (FP params).
    pub grad: Tensor,
    /// Boolean accumulator m_t (Eq. 10).
    pub accum: Tensor,
    /// Per-tensor unchanged-ratio β_t (Eq. 11); starts at 1.
    pub ratio: f32,
    /// Adam first moment (FP params).
    pub adam_m: Vec<f32>,
    /// Adam second moment (FP params).
    pub adam_v: Vec<f32>,
}

impl ParamSlot {
    fn new() -> Self {
        ParamSlot {
            grad: Tensor::zeros(&[0]),
            accum: Tensor::zeros(&[0]),
            ratio: 1.0,
            adam_m: Vec::new(),
            adam_v: Vec::new(),
        }
    }

    /// Grad buffer shaped like `shape`, allocating zeros on first touch.
    pub fn grad_mut(&mut self, shape: &[usize]) -> &mut Tensor {
        if self.grad.is_empty() {
            self.grad = Tensor::zeros(shape);
        }
        debug_assert_eq!(self.grad.len(), shape.iter().product::<usize>());
        &mut self.grad
    }

    /// Accumulator sized to `len` elements (flat), allocating on first use.
    pub fn accum_mut(&mut self, len: usize) -> &mut Tensor {
        if self.accum.is_empty() && len > 0 {
            self.accum = Tensor::zeros(&[len]);
        }
        assert_eq!(self.accum.len(), len, "accumulator changed size");
        &mut self.accum
    }

    /// Adam moment vectors sized to `len` (allocated zeroed on first use).
    pub fn adam_mut(&mut self, len: usize) -> (&mut Vec<f32>, &mut Vec<f32>) {
        if self.adam_m.is_empty() && len > 0 {
            self.adam_m = vec![0.0; len];
            self.adam_v = vec![0.0; len];
        }
        assert_eq!(self.adam_m.len(), len, "adam state changed size");
        (&mut self.adam_m, &mut self.adam_v)
    }
}

/// The central parameter-state store (see module docs).
///
/// ```
/// use bold::nn::ParamStore;
/// use bold::tensor::Tensor;
///
/// let mut store = ParamStore::new();
/// store.accumulate("fc.w", &Tensor::from_vec(&[1, 2], vec![1.0, 2.0]));
/// store.accumulate("fc.w", &Tensor::from_vec(&[1, 2], vec![1.0, 2.0]));
/// assert_eq!(store.grad("fc.w").unwrap().data, vec![2.0, 4.0]);
/// store.zero_grads();
/// assert_eq!(store.grad("fc.w").unwrap().data, vec![0.0, 0.0]);
/// ```
#[derive(Debug, Clone, Default)]
pub struct ParamStore {
    names: Vec<String>,
    index: HashMap<String, usize>,
    slots: Vec<ParamSlot>,
    /// Shared Adam timestep (bias-correction t); serialized for resume.
    pub adam_t: u64,
}

impl ParamStore {
    pub fn new() -> Self {
        ParamStore { names: Vec::new(), index: HashMap::new(), slots: Vec::new(), adam_t: 0 }
    }

    /// Number of registered parameters.
    pub fn len(&self) -> usize {
        self.slots.len()
    }

    pub fn is_empty(&self) -> bool {
        self.slots.is_empty()
    }

    /// Register `name` (idempotent) and return its stable id.
    pub fn register(&mut self, name: &str) -> ParamId {
        if let Some(&i) = self.index.get(name) {
            return ParamId(i);
        }
        let i = self.slots.len();
        self.names.push(name.to_string());
        self.index.insert(name.to_string(), i);
        self.slots.push(ParamSlot::new());
        ParamId(i)
    }

    /// Name of a registered parameter.
    pub fn name(&self, id: ParamId) -> &str {
        &self.names[id.0]
    }

    /// Registered names, in registration order.
    pub fn names(&self) -> impl Iterator<Item = &str> {
        self.names.iter().map(|s| s.as_str())
    }

    /// Slot by name, if registered.
    pub fn slot(&self, name: &str) -> Option<&ParamSlot> {
        self.index.get(name).map(|&i| &self.slots[i])
    }

    /// Slot by name, registering on first touch.
    pub fn slot_mut(&mut self, name: &str) -> &mut ParamSlot {
        let id = self.register(name);
        &mut self.slots[id.0]
    }

    /// Slot by id.
    pub fn slot_by_id_mut(&mut self, id: ParamId) -> &mut ParamSlot {
        &mut self.slots[id.0]
    }

    /// grad[name] += delta (registering and zero-initializing on first
    /// touch). This is the one call every layer backward makes.
    pub fn accumulate(&mut self, name: &str, delta: &Tensor) {
        let slot = self.slot_mut(name);
        if slot.grad.is_empty() {
            slot.grad = delta.clone();
        } else {
            slot.grad.add_inplace(delta);
        }
    }

    /// The accumulated vote/gradient for `name`, if any.
    pub fn grad(&self, name: &str) -> Option<&Tensor> {
        self.slot(name).filter(|s| !s.grad.is_empty()).map(|s| &s.grad)
    }

    /// Zero every grad buffer (start of a step). Allocations are kept.
    pub fn zero_grads(&mut self) {
        for s in self.slots.iter_mut() {
            s.grad.scale_inplace(0.0);
        }
    }

    /// Vote aggregation (Appendix D.1.1): add every grad buffer of
    /// `other` into this store. Because Eq. 7 votes are additive over
    /// samples, summing worker stores is exactly the big-batch step.
    pub fn add_grads_from(&mut self, other: &ParamStore) {
        for (name, slot) in other.names.iter().zip(&other.slots) {
            if !slot.grad.is_empty() {
                self.accumulate(name, &slot.grad);
            }
        }
    }

    /// Serialize every non-empty grad buffer to a flat little-endian blob
    /// (the per-shard vote delta a `train-dist` worker ships to the
    /// coordinator). Entries are written in registration order and carry
    /// raw f32 bit patterns, so
    /// `a.add_grads_from(&ParamStore::from_grad_blob(&b.grad_blob())?)`
    /// is bit-identical to `a.add_grads_from(&b)` — the property the
    /// distributed determinism argument rests on (DESIGN.md
    /// §Distributed-Training).
    pub fn grad_blob(&self) -> Vec<u8> {
        let mut out = Vec::new();
        let live: Vec<(&String, &ParamSlot)> = self
            .names
            .iter()
            .zip(&self.slots)
            .filter(|(_, s)| !s.grad.is_empty())
            .collect();
        out.extend_from_slice(&(live.len() as u32).to_le_bytes());
        for (name, slot) in live {
            out.extend_from_slice(&(name.len() as u32).to_le_bytes());
            out.extend_from_slice(name.as_bytes());
            out.extend_from_slice(&(slot.grad.shape.len() as u32).to_le_bytes());
            for &d in &slot.grad.shape {
                out.extend_from_slice(&(d as u32).to_le_bytes());
            }
            for &v in &slot.grad.data {
                out.extend_from_slice(&v.to_le_bytes());
            }
        }
        out
    }

    /// Inverse of [`ParamStore::grad_blob`]: rebuild a delta store with
    /// the same registration order and bit-identical grad values. Rejects
    /// truncated or structurally inconsistent blobs instead of panicking —
    /// wire input is untrusted.
    pub fn from_grad_blob(blob: &[u8]) -> Result<ParamStore, String> {
        fn take<'a>(blob: &'a [u8], pos: &mut usize, n: usize) -> Result<&'a [u8], String> {
            let end = pos.checked_add(n).ok_or("grad blob: length overflow")?;
            if end > blob.len() {
                return Err(format!("grad blob: truncated at byte {pos} (want {n} more)"));
            }
            let s = &blob[*pos..end];
            *pos = end;
            Ok(s)
        }
        fn r_u32(blob: &[u8], pos: &mut usize) -> Result<u32, String> {
            let b = take(blob, pos, 4)?;
            Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
        }
        let mut pos = 0usize;
        let mut store = ParamStore::new();
        let n = r_u32(blob, &mut pos)?;
        for _ in 0..n {
            let name_len = r_u32(blob, &mut pos)? as usize;
            let name = String::from_utf8(take(blob, &mut pos, name_len)?.to_vec())
                .map_err(|_| "grad blob: non-utf8 parameter name".to_string())?;
            let rank = r_u32(blob, &mut pos)? as usize;
            if rank > 8 {
                return Err(format!("grad blob: implausible rank {rank} for '{name}'"));
            }
            let mut shape = Vec::with_capacity(rank);
            let mut len = 1usize;
            for _ in 0..rank {
                let d = r_u32(blob, &mut pos)? as usize;
                len = len
                    .checked_mul(d)
                    .ok_or_else(|| format!("grad blob: shape overflow for '{name}'"))?;
                shape.push(d);
            }
            let bytes =
                take(blob, &mut pos, len.checked_mul(4).ok_or("grad blob: size overflow")?)?;
            let data: Vec<f32> = bytes
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect();
            let slot = store.slot_mut(&name);
            slot.grad = Tensor::from_vec(&shape, data);
        }
        if pos != blob.len() {
            return Err(format!("grad blob: {} trailing bytes", blob.len() - pos));
        }
        Ok(store)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn register_is_idempotent_and_stable() {
        let mut s = ParamStore::new();
        let a = s.register("a");
        let b = s.register("b");
        assert_eq!(s.register("a"), a);
        assert_ne!(a, b);
        assert_eq!(s.name(a), "a");
        assert_eq!(s.len(), 2);
    }

    #[test]
    fn accumulate_sums_and_zero_keeps_allocation() {
        let mut s = ParamStore::new();
        let d = Tensor::from_vec(&[2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        s.accumulate("w", &d);
        s.accumulate("w", &d);
        assert_eq!(s.grad("w").unwrap().data, vec![2.0, 4.0, 6.0, 8.0]);
        s.zero_grads();
        // zeroed but still shaped (and `grad()` hides nothing: len > 0)
        assert_eq!(s.grad("w").unwrap().len(), 4);
        assert_eq!(s.grad("w").unwrap().sum(), 0.0);
    }

    #[test]
    fn add_grads_from_is_vote_addition() {
        let mut a = ParamStore::new();
        let mut b = ParamStore::new();
        a.accumulate("w", &Tensor::from_vec(&[2], vec![1.0, -1.0]));
        b.accumulate("w", &Tensor::from_vec(&[2], vec![0.5, 2.0]));
        b.accumulate("only_b", &Tensor::from_vec(&[1], vec![7.0]));
        a.add_grads_from(&b);
        assert_eq!(a.grad("w").unwrap().data, vec![1.5, 1.0]);
        assert_eq!(a.grad("only_b").unwrap().data, vec![7.0]);
    }

    #[test]
    fn grad_blob_round_trips_bit_exactly() {
        let mut s = ParamStore::new();
        s.accumulate("fc1.w", &Tensor::from_vec(&[2, 3], vec![1.0, -0.0, 1.5e-39, f32::MIN_POSITIVE, 3.25, -7.75]));
        s.accumulate("fc2.b", &Tensor::from_vec(&[2], vec![0.1, -0.1]));
        // empty-grad slot must be skipped, not serialized as a zero tensor
        s.register("frozen.w");

        let blob = s.grad_blob();
        let back = ParamStore::from_grad_blob(&blob).unwrap();
        assert_eq!(back.len(), 2);
        let names: Vec<&str> = back.names().collect();
        assert_eq!(names, vec!["fc1.w", "fc2.b"], "registration order preserved");
        for name in ["fc1.w", "fc2.b"] {
            let (a, b) = (s.grad(name).unwrap(), back.grad(name).unwrap());
            assert_eq!(a.shape, b.shape);
            let (ab, bb): (Vec<u32>, Vec<u32>) = (
                a.data.iter().map(|v| v.to_bits()).collect(),
                b.data.iter().map(|v| v.to_bits()).collect(),
            );
            assert_eq!(ab, bb, "'{name}' must round-trip bit-exactly (incl. -0.0, denormals)");
        }

        // aggregation through the blob is bit-identical to direct aggregation
        let mut direct = ParamStore::new();
        direct.accumulate("fc1.w", &Tensor::from_vec(&[2, 3], vec![0.5; 6]));
        let mut via_blob = direct.clone();
        direct.add_grads_from(&s);
        via_blob.add_grads_from(&back);
        for name in ["fc1.w", "fc2.b"] {
            let (a, b) = (direct.grad(name).unwrap(), via_blob.grad(name).unwrap());
            assert!(a.data.iter().zip(&b.data).all(|(x, y)| x.to_bits() == y.to_bits()));
        }
    }

    #[test]
    fn grad_blob_rejects_corruption_without_panicking() {
        let mut s = ParamStore::new();
        s.accumulate("w", &Tensor::from_vec(&[4], vec![1.0, 2.0, 3.0, 4.0]));
        let blob = s.grad_blob();

        // every truncation point must error, never panic or return Ok
        for cut in 0..blob.len() {
            assert!(ParamStore::from_grad_blob(&blob[..cut]).is_err(), "truncation at {cut}");
        }
        // trailing garbage is rejected too
        let mut padded = blob.clone();
        padded.push(0);
        assert!(ParamStore::from_grad_blob(&padded).is_err());
        // absurd entry count from a torn length prefix
        let mut huge = blob.clone();
        huge[0..4].copy_from_slice(&u32::MAX.to_le_bytes());
        assert!(ParamStore::from_grad_blob(&huge).is_err());
    }

    #[test]
    fn slots_lazily_size_their_buffers() {
        let mut s = ParamStore::new();
        let slot = s.slot_mut("w");
        assert!(slot.grad.is_empty());
        slot.accum_mut(8);
        assert_eq!(slot.accum.len(), 8);
        let (m, v) = slot.adam_mut(4);
        assert_eq!(m.len(), 4);
        assert_eq!(v.len(), 4);
        assert_eq!(slot.ratio, 1.0);
    }
}
