//! Composition: sequential stacks, flatten, and the residual block used by
//! the Boolean ResNet/EDSR architectures (paper Appendix D.1.3 "Block I":
//! both paths end on integer pre-activations, summed before activation).

use super::{Layer, LayerDesc, ParamRef, ParamStore, Value};
use crate::tensor::Tensor;

/// A stack of layers applied in order.
pub struct Sequential {
    pub layers: Vec<Box<dyn Layer>>,
    name: String,
    /// Non-batch dims of the most recent forward — recorded so
    /// `save_model` can embed the input geometry in `Record::Arch`
    /// ([`Layer::input_shape`]).
    last_input_shape: Option<Vec<usize>>,
}

impl Sequential {
    pub fn new(name: &str) -> Self {
        Sequential { layers: Vec::new(), name: name.to_string(), last_input_shape: None }
    }

    pub fn push(&mut self, l: Box<dyn Layer>) -> &mut Self {
        self.layers.push(l);
        self
    }

    pub fn with(mut self, l: Box<dyn Layer>) -> Self {
        self.layers.push(l);
        self
    }

    pub fn len(&self) -> usize {
        self.layers.len()
    }

    pub fn is_empty(&self) -> bool {
        self.layers.is_empty()
    }
}

impl Layer for Sequential {
    fn forward(&mut self, mut x: Value, train: bool) -> Value {
        let dims = &x.shape()[1..];
        if self.last_input_shape.as_deref() != Some(dims) {
            self.last_input_shape = Some(dims.to_vec());
        }
        for l in self.layers.iter_mut() {
            x = l.forward(x, train);
        }
        x
    }

    fn backward(&mut self, mut z: Tensor, store: &mut ParamStore) -> Tensor {
        for l in self.layers.iter_mut().rev() {
            z = l.backward(z, store);
        }
        z
    }

    fn params(&mut self) -> Vec<ParamRef<'_>> {
        self.layers.iter_mut().flat_map(|l| l.params()).collect()
    }

    fn buffers(&mut self) -> Vec<(String, &mut Vec<f32>)> {
        self.layers.iter_mut().flat_map(|l| l.buffers()).collect()
    }

    fn name(&self) -> String {
        self.name.clone()
    }

    /// Concatenation of the children's descriptions; `None` as soon as
    /// any child is not describable.
    fn describe(&self) -> Option<Vec<LayerDesc>> {
        let mut out = Vec::with_capacity(self.layers.len());
        for l in &self.layers {
            out.extend(l.describe()?);
        }
        Some(out)
    }

    fn input_shape(&self) -> Option<Vec<usize>> {
        self.last_input_shape.clone()
    }
}

/// Flatten any value to (batch, features). For Bit values this is free
/// (shape metadata only).
pub struct Flatten {
    name: String,
    cache_shape: Option<Vec<usize>>,
}

impl Flatten {
    pub fn new(name: &str) -> Self {
        Flatten { name: name.to_string(), cache_shape: None }
    }
}

impl Layer for Flatten {
    fn forward(&mut self, x: Value, train: bool) -> Value {
        if train {
            self.cache_shape = Some(x.shape().to_vec());
        }
        let b = x.batch();
        let cols: usize = x.shape()[1..].iter().product();
        match x {
            Value::F32(t) => Value::F32(t.reshape(&[b, cols])),
            Value::Bit { bits, .. } => Value::Bit { bits, shape: vec![b, cols] },
        }
    }

    fn backward(&mut self, z: Tensor, _store: &mut ParamStore) -> Tensor {
        let shape = self.cache_shape.as_ref().expect("backward before forward");
        z.reshape(shape)
    }

    fn name(&self) -> String {
        self.name.clone()
    }

    fn describe(&self) -> Option<Vec<LayerDesc>> {
        Some(vec![LayerDesc::Flatten { name: self.name.clone() }])
    }
}

/// Residual block: `out = main(x) + shortcut(x)` on f32 (integer-valued)
/// pre-activations, the summation point of the paper's Block I. The input
/// value is cloned into both paths; the backward signal is routed through
/// both and the upstream contributions are *summed* — this is Theorem
/// 3.11(3) (additivity of the variation) in layer form.
pub struct Residual {
    pub main: Sequential,
    pub shortcut: Sequential,
    name: String,
}

impl Residual {
    pub fn new(name: &str, main: Sequential, shortcut: Sequential) -> Self {
        Residual { main, shortcut, name: name.to_string() }
    }
}

impl Layer for Residual {
    fn forward(&mut self, x: Value, train: bool) -> Value {
        let a = self.main.forward(x.clone(), train).expect_f32("residual main");
        let b = if self.shortcut.is_empty() {
            x.to_f32()
        } else {
            self.shortcut.forward(x, train).expect_f32("residual shortcut")
        };
        assert_eq!(a.shape, b.shape, "{}: path shapes {:?} vs {:?}", self.name, a.shape, b.shape);
        Value::F32(a.add(&b))
    }

    fn backward(&mut self, z: Tensor, store: &mut ParamStore) -> Tensor {
        let g_main = self.main.backward(z.clone(), store);
        let g_short = if self.shortcut.is_empty() {
            z
        } else {
            self.shortcut.backward(z, store)
        };
        assert_eq!(g_main.shape, g_short.shape, "{}: backward shapes", self.name);
        g_main.add(&g_short)
    }

    fn params(&mut self) -> Vec<ParamRef<'_>> {
        let mut v = self.main.params();
        v.extend(self.shortcut.params());
        v
    }

    fn buffers(&mut self) -> Vec<(String, &mut Vec<f32>)> {
        let mut v = self.main.buffers();
        v.extend(self.shortcut.buffers());
        v
    }

    fn name(&self) -> String {
        self.name.clone()
    }

    /// One nested desc with both branch op lists (an empty `shortcut`
    /// list is the identity shortcut).
    fn describe(&self) -> Option<Vec<LayerDesc>> {
        Some(vec![LayerDesc::Residual {
            name: self.name.clone(),
            main: self.main.describe()?,
            shortcut: self.shortcut.describe()?,
        }])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::{BackwardScale, BoolLinear, Linear, ThresholdAct};
    use crate::util::Rng;

    #[test]
    fn sequential_chains_forward_backward() {
        let mut rng = Rng::new(1);
        let mut net = Sequential::new("net")
            .with(Box::new(BoolLinear::new("l1", 64, 32, &mut rng)))
            .with(Box::new(ThresholdAct::new("a1", 0.0, BackwardScale::TanhPrime { fanin: 64 })))
            .with(Box::new(Linear::new("fc", 32, 4, &mut rng)));
        let x = Tensor::rand_pm1(&[8, 64], &mut rng);
        let y = net.forward(Value::bit_from_pm1(&x), true).expect_f32("t");
        assert_eq!(y.shape, vec![8, 4]);
        let g = net.backward(Tensor::full(&[8, 4], 1.0), &mut ParamStore::new());
        assert_eq!(g.shape, vec![8, 64]);
        assert_eq!(net.params().len(), 3); // bool w, fc w, fc b
    }

    #[test]
    fn flatten_roundtrip() {
        let mut rng = Rng::new(2);
        let mut f = Flatten::new("fl");
        let x = Tensor::rand_pm1(&[2, 3, 4, 4], &mut rng);
        let y = f.forward(Value::bit_from_pm1(&x), true);
        assert_eq!(y.shape(), &[2, 48]);
        let g = f.backward(Tensor::zeros(&[2, 48]), &mut ParamStore::new());
        assert_eq!(g.shape, vec![2, 3, 4, 4]);
    }

    #[test]
    fn residual_identity_shortcut_adds_input() {
        let mut rng = Rng::new(3);
        // main: linear with zero weights ⇒ out == input (identity shortcut)
        let mut lin = Linear::new("l", 8, 8, &mut rng);
        lin.w.scale_inplace(0.0);
        lin.b.scale_inplace(0.0);
        let main = Sequential::new("m").with(Box::new(lin));
        let mut res = Residual::new("res", main, Sequential::new("s"));
        let x = Tensor::randn(&[2, 8], 1.0, &mut rng);
        let y = res.forward(Value::F32(x.clone()), true).expect_f32("t");
        assert!(y.max_abs_diff(&x) < 1e-6);
        // backward: identity shortcut passes z, main contributes W᷀z = 0
        let g = res.backward(Tensor::full(&[2, 8], 1.0), &mut ParamStore::new());
        assert!(g.max_abs_diff(&Tensor::full(&[2, 8], 1.0)) < 1e-6);
    }

    #[test]
    fn residual_backward_sums_both_paths() {
        let mut rng = Rng::new(4);
        let mk = |rng: &mut Rng| {
            let mut l = Linear::new("l", 4, 4, rng);
            // identity weights
            l.w.scale_inplace(0.0);
            for i in 0..4 {
                *l.w.at2_mut(i, i) = 1.0;
            }
            l
        };
        let main = Sequential::new("m").with(Box::new(mk(&mut rng)));
        let short = Sequential::new("s").with(Box::new(mk(&mut rng)));
        let mut res = Residual::new("res", main, short);
        let x = Tensor::randn(&[1, 4], 1.0, &mut rng);
        let y = res.forward(Value::F32(x.clone()), true).expect_f32("t");
        assert!(y.max_abs_diff(&x.scale(2.0)) < 1e-6);
        let g = res.backward(Tensor::full(&[1, 4], 1.0), &mut ParamStore::new());
        assert!(g.max_abs_diff(&Tensor::full(&[1, 4], 2.0)) < 1e-6);
    }
}
