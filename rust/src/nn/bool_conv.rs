//! Boolean 2-D convolution: the conv form of the paper's Boolean neuron.
//!
//! Conv = bit-level im2col + the same xnor-popcount GEMM as `BoolLinear`.
//! Zero padding is the adjoined 0 of the three-valued logic 𝕄
//! (Definition 3.1): padded taps are carried in a validity *mask* and
//! contribute nothing to the count — forward uses
//! [`BitMatrix::xnor_gemm_masked`], the weight vote uses
//! [`BitMatrix::backward_weight_masked`].

use super::{Layer, ParamRef, ParamStore, Value};
use crate::tensor::{BitMatrix, Tensor};
use crate::util::Rng;

/// Boolean Conv2d (NCHW, square kernel). Weight votes are accumulated in
/// the [`ParamStore`] under `<name>.weight`.
pub struct BoolConv2d {
    pub c_in: usize,
    pub c_out: usize,
    pub k: usize,
    pub stride: usize,
    pub pad: usize,
    /// Packed weights: `c_out` rows of `c_in·k·k` bits.
    pub weights: BitMatrix,
    pub bool_bprop: bool,
    name: String,
    // caches
    cache_patches: Option<BitMatrix>,
    cache_mask: Option<BitMatrix>,
    cache_dims: Option<(usize, usize, usize, usize, usize)>, // n, h, w, oh, ow
    /// Geometry-keyed validity-mask cache: (n, h, w, mask).
    cache_mask_geom: Option<(usize, usize, usize, BitMatrix)>,
}

impl BoolConv2d {
    pub fn new(
        name: &str,
        c_in: usize,
        c_out: usize,
        k: usize,
        stride: usize,
        pad: usize,
        rng: &mut Rng,
    ) -> Self {
        let fanin = c_in * k * k;
        BoolConv2d {
            c_in,
            c_out,
            k,
            stride,
            pad,
            weights: BitMatrix::random(c_out, fanin, rng),
            bool_bprop: false,
            name: name.to_string(),
            cache_patches: None,
            cache_mask: None,
            cache_dims: None,
            cache_mask_geom: None,
        }
    }

    pub fn with_bool_bprop(mut self) -> Self {
        self.bool_bprop = true;
        self
    }

    pub fn fanin(&self) -> usize {
        self.c_in * self.k * self.k
    }

    /// Store key of the weight parameter.
    pub fn weight_key(&self) -> String {
        format!("{}.weight", self.name)
    }

    /// Output spatial size for an input of size (h, w).
    pub fn out_hw(&self, h: usize, w: usize) -> (usize, usize) {
        (
            (h + 2 * self.pad - self.k) / self.stride + 1,
            (w + 2 * self.pad - self.k) / self.stride + 1,
        )
    }

    /// Bit-level im2col: patches (N·OH·OW × C·k·k) + validity mask.
    ///
    /// The k taps along x map to *consecutive* source columns, so each
    /// (output-row, channel, ky) copies one ≤k-bit run with a single
    /// word-level `get_bits`/`set_bits` pair — ~k× fewer bit ops than the
    /// naive per-tap loop (§Perf iteration log). The mask depends only on
    /// the geometry, so it is built once and cached by the layer.
    fn bit_im2col(
        &mut self,
        bits: &BitMatrix,
        n: usize,
        h: usize,
        w: usize,
    ) -> (BitMatrix, BitMatrix, usize, usize) {
        let (oh, ow) = self.out_hw(h, w);
        let (c, k, s, p) = (self.c_in, self.k, self.stride, self.pad);
        assert!(k <= 56, "kernel too large for word-level im2col");
        let cols = c * k * k;
        let mut patches = BitMatrix::zeros(n * oh * ow, cols);
        let build_mask = match &self.cache_mask_geom {
            Some((gn, gh, gw, _)) if (*gn, *gh, *gw) == (n, h, w) => false,
            _ => true,
        };
        let mut mask = if build_mask {
            BitMatrix::zeros(n * oh * ow, cols)
        } else {
            BitMatrix::zeros(0, 0) // placeholder, replaced below
        };
        for ni in 0..n {
            for oy in 0..oh {
                for ox in 0..ow {
                    let row = (ni * oh + oy) * ow + ox;
                    // valid kx range is contiguous: ix = ox·s + kx − p ∈ [0, w)
                    let kx_lo = p.saturating_sub(ox * s).min(k);
                    let kx_hi = k.min((w + p).saturating_sub(ox * s));
                    if kx_lo >= kx_hi {
                        continue;
                    }
                    let run = kx_hi - kx_lo;
                    let ix0 = ox * s + kx_lo - p;
                    for ky in 0..k {
                        let iy = (oy * s + ky) as isize - p as isize;
                        if iy < 0 || iy >= h as isize {
                            continue;
                        }
                        for ci in 0..c {
                            let src_col = (ci * h + iy as usize) * w + ix0;
                            let dst_col = (ci * k + ky) * k + kx_lo;
                            let chunk = bits.get_bits(ni, src_col, run);
                            patches.set_bits(row, dst_col, run, chunk);
                            if build_mask {
                                mask.set_bits(row, dst_col, run, u64::MAX);
                            }
                        }
                    }
                }
            }
        }
        if build_mask {
            self.cache_mask_geom = Some((n, h, w, mask));
        }
        let mask = self.cache_mask_geom.as_ref().unwrap().3.clone();
        (patches, mask, oh, ow)
    }
}

impl Layer for BoolConv2d {
    fn forward(&mut self, x: Value, train: bool) -> Value {
        let (bits, shape) = x.expect_bit(&self.name);
        assert_eq!(shape.len(), 4, "{}: need NCHW", self.name);
        let (n, c, h, w) = (shape[0], shape[1], shape[2], shape[3]);
        assert_eq!(c, self.c_in, "{}: channel mismatch", self.name);
        let (patches, mask, oh, ow) = self.bit_im2col(&bits, n, h, w);
        let s_rows = patches.xnor_gemm_masked(&self.weights, &mask); // (N·OH·OW × Cout)
        let s = s_rows.rows_to_nchw(n, self.c_out, oh, ow);
        if train {
            self.cache_patches = Some(patches);
            self.cache_mask = Some(mask);
            self.cache_dims = Some((n, h, w, oh, ow));
        }
        Value::F32(s)
    }

    fn backward(&mut self, z: Tensor, store: &mut ParamStore) -> Tensor {
        let (n, h, w, oh, ow) = self.cache_dims.expect("backward before forward");
        assert_eq!(z.shape, vec![n, self.c_out, oh, ow], "{}: bad z", self.name);
        let z_rows = z.nchw_to_rows(); // (N·OH·OW × Cout)
        let patches = self.cache_patches.as_ref().unwrap();
        let mask = self.cache_mask.as_ref().unwrap();

        // Weight vote (Eq. 7): padded taps vote 0.
        let q_w = patches.backward_weight_masked(&z_rows, mask);
        store.accumulate(&self.weight_key(), &q_w);

        // Upstream signal (Eq. 8): scatter the patch-level signal back to
        // input positions. Padded lanes are dropped by col2im geometry —
        // the same masking, expressed spatially.
        let g_cols = self.weights.backward_input(&z_rows); // (N·OH·OW × C·k·k)
        let mut g_x = g_cols.col2im(n, self.c_in, h, w, self.k, self.stride, self.pad);
        if self.bool_bprop {
            g_x = g_x.sign_pm1();
        }
        g_x
    }

    fn params(&mut self) -> Vec<ParamRef<'_>> {
        let name = self.weight_key();
        vec![ParamRef::Bool { name, bits: &mut self.weights }]
    }

    fn name(&self) -> String {
        self.name.clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Dense reference conv in the embedded domain with 𝕄-zero padding.
    fn ref_conv(x: &Tensor, wbits: &BitMatrix, c_out: usize, k: usize, s: usize, p: usize) -> Tensor {
        let cols = x.im2col(k, s, p); // zero padding == e(0)
        let w = wbits.to_pm1();
        let (n, _c, h, wd) = x.dims4();
        let oh = (h + 2 * p - k) / s + 1;
        let ow = (wd + 2 * p - k) / s + 1;
        cols.matmul_bt(&w).rows_to_nchw(n, c_out, oh, ow)
    }

    #[test]
    fn forward_matches_dense_embedded_conv() {
        let mut rng = Rng::new(1);
        for (s, p) in [(1, 1), (1, 0), (2, 1)] {
            let mut conv = BoolConv2d::new("bc", 3, 5, 3, s, p, &mut rng);
            let x = Tensor::rand_pm1(&[2, 3, 8, 8], &mut rng);
            let out = conv.forward(Value::bit_from_pm1(&x), true).expect_f32("t");
            let want = ref_conv(&x, &conv.weights, 5, 3, s, p);
            assert_eq!(out.max_abs_diff(&want), 0.0, "s={s} p={p}");
        }
    }

    #[test]
    fn backward_weight_vote_matches_dense() {
        let mut rng = Rng::new(2);
        let mut conv = BoolConv2d::new("bc", 2, 4, 3, 1, 1, &mut rng);
        let mut store = ParamStore::new();
        let x = Tensor::rand_pm1(&[2, 2, 6, 6], &mut rng);
        let _ = conv.forward(Value::bit_from_pm1(&x), true);
        let z = Tensor::randn(&[2, 4, 6, 6], 1.0, &mut rng);
        let _ = conv.backward(z.clone(), &mut store);
        // dense: q_w = z_rowsᵀ @ cols (cols with 0 at padded taps)
        let cols = x.im2col(3, 1, 1);
        let q_ref = z.nchw_to_rows().matmul_at(&cols);
        assert!(store.grad("bc.weight").unwrap().max_abs_diff(&q_ref) < 1e-3);
    }

    #[test]
    fn backward_input_matches_dense() {
        let mut rng = Rng::new(3);
        let mut conv = BoolConv2d::new("bc", 2, 3, 3, 1, 1, &mut rng);
        let mut store = ParamStore::new();
        let x = Tensor::rand_pm1(&[1, 2, 5, 5], &mut rng);
        let _ = conv.forward(Value::bit_from_pm1(&x), true);
        let z = Tensor::randn(&[1, 3, 5, 5], 1.0, &mut rng);
        let g = conv.backward(z.clone(), &mut store);
        let g_cols = z.nchw_to_rows().matmul(&conv.weights.to_pm1());
        let g_ref = g_cols.col2im(1, 2, 5, 5, 3, 1, 1);
        assert!(g.max_abs_diff(&g_ref) < 1e-3);
    }

    #[test]
    fn strided_shapes() {
        let mut rng = Rng::new(4);
        let mut conv = BoolConv2d::new("bc", 3, 8, 3, 2, 1, &mut rng);
        let x = Tensor::rand_pm1(&[2, 3, 8, 8], &mut rng);
        let out = conv.forward(Value::bit_from_pm1(&x), false).expect_f32("t");
        assert_eq!(out.shape, vec![2, 8, 4, 4]);
    }

    #[test]
    fn preactivation_range_respects_valid_fanin() {
        // Interior positions see full fan-in; corners see fewer valid taps.
        let mut rng = Rng::new(5);
        let mut conv = BoolConv2d::new("bc", 1, 1, 3, 1, 1, &mut rng);
        let x = Tensor::rand_pm1(&[1, 1, 4, 4], &mut rng);
        let out = conv.forward(Value::bit_from_pm1(&x), false).expect_f32("t");
        // corner has 4 valid taps → |s| ≤ 4; interior ≤ 9
        assert!(out.data[0].abs() <= 4.0);
        let interior = out.data[1 * 4 + 1]; // position (0,0,1,1)
        assert!(interior.abs() <= 9.0);
    }
}
