//! Boolean 2-D convolution: the conv form of the paper's Boolean neuron.
//!
//! Conv = bit-level im2col + the same xnor-popcount GEMM as `BoolLinear`.
//! Zero padding is the adjoined 0 of the three-valued logic 𝕄
//! (Definition 3.1): padded taps are carried in a validity *mask* and
//! contribute nothing to the count — forward uses
//! [`BitMatrix::xnor_gemm_masked`], the weight vote uses
//! [`BitMatrix::backward_weight_masked`].

use super::{Layer, LayerDesc, ParamRef, ParamStore, Value};
use crate::tensor::{BitMatrix, Tensor};
use crate::util::Rng;

/// Boolean Conv2d (NCHW, square kernel). Weight votes are accumulated in
/// the [`ParamStore`] under `<name>.weight`.
pub struct BoolConv2d {
    pub c_in: usize,
    pub c_out: usize,
    pub k: usize,
    pub stride: usize,
    pub pad: usize,
    /// Packed weights: `c_out` rows of `c_in·k·k` bits.
    pub weights: BitMatrix,
    pub bool_bprop: bool,
    name: String,
    // --- caches and reusable scratch (steady-state training and
    // inference allocate nothing below; buffers are reshaped in place) ---
    /// Bit-im2col patches of the latest forward (backward reads them).
    patches: BitMatrix,
    /// Set by a train-mode forward; `None` blocks backward after eval.
    cache_dims: Option<(usize, usize, usize, usize, usize)>, // n, h, w, oh, ow
    /// Geometry key (n, h, w) for which `mask` is valid.
    mask_geom: Option<(usize, usize, usize)>,
    /// Validity mask (𝕄 zeros at padded taps); depends only on geometry,
    /// so it is rebuilt only when the input geometry changes.
    mask: BitMatrix,
    /// GEMM pre-activation rows (N·OH·OW × Cout).
    scratch_s: Tensor,
    /// Weight-vote buffer for Eq. (7).
    scratch_qw: Tensor,
    /// Patch-level upstream signal (N·OH·OW × C·k·k).
    scratch_gcols: Tensor,
}

impl BoolConv2d {
    pub fn new(
        name: &str,
        c_in: usize,
        c_out: usize,
        k: usize,
        stride: usize,
        pad: usize,
        rng: &mut Rng,
    ) -> Self {
        let fanin = c_in * k * k;
        BoolConv2d {
            c_in,
            c_out,
            k,
            stride,
            pad,
            weights: BitMatrix::random(c_out, fanin, rng),
            bool_bprop: false,
            name: name.to_string(),
            patches: BitMatrix::zeros(0, 0),
            cache_dims: None,
            mask_geom: None,
            mask: BitMatrix::zeros(0, 0),
            scratch_s: Tensor::zeros(&[0]),
            scratch_qw: Tensor::zeros(&[0]),
            scratch_gcols: Tensor::zeros(&[0]),
        }
    }

    pub fn with_bool_bprop(mut self) -> Self {
        self.bool_bprop = true;
        self
    }

    pub fn fanin(&self) -> usize {
        self.c_in * self.k * self.k
    }

    /// Store key of the weight parameter.
    pub fn weight_key(&self) -> String {
        format!("{}.weight", self.name)
    }

    /// Output spatial size for an input of size (h, w).
    pub fn out_hw(&self, h: usize, w: usize) -> (usize, usize) {
        (
            (h + 2 * self.pad - self.k) / self.stride + 1,
            (w + 2 * self.pad - self.k) / self.stride + 1,
        )
    }

    /// Bit-level im2col into the layer's reusable `patches` buffer, plus
    /// the geometry-cached validity mask (see [`packed_im2col`]). The mask
    /// depends only on the geometry, so it is rebuilt only when (n, h, w)
    /// changes and is borrowed (never cloned) by forward/backward.
    fn bit_im2col(&mut self, bits: &BitMatrix, n: usize, h: usize, w: usize) -> (usize, usize) {
        let build_mask = self.mask_geom != Some((n, h, w));
        let (oh, ow) = packed_im2col(
            bits,
            n,
            self.c_in,
            h,
            w,
            self.k,
            self.stride,
            self.pad,
            &mut self.patches,
            &mut self.mask,
            build_mask,
        );
        if build_mask {
            self.mask_geom = Some((n, h, w));
        }
        (oh, ow)
    }
}

/// Bit-level im2col core, shared by the training layer above and the
/// serving graph executor (`runtime::graph`) so the parity-critical
/// geometry logic exists exactly once.
///
/// The k taps along x map to *consecutive* source columns, so each
/// (output-row, channel, ky) copies one ≤k-bit run with a single
/// word-level `get_bits`/`set_bits` pair — ~k× fewer bit ops than the
/// naive per-tap loop (§Perf iteration log). `patches` is reshaped and
/// rebuilt every call; `mask` only when `build_mask` (its content depends
/// solely on the (n, h, w) geometry, which the caller caches).
pub(crate) fn packed_im2col(
    bits: &BitMatrix,
    n: usize,
    c: usize,
    h: usize,
    w: usize,
    k: usize,
    s: usize,
    p: usize,
    patches: &mut BitMatrix,
    mask: &mut BitMatrix,
    build_mask: bool,
) -> (usize, usize) {
    assert!(k <= 56, "kernel too large for word-level im2col");
    let oh = (h + 2 * p - k) / s + 1;
    let ow = (w + 2 * p - k) / s + 1;
    let cols = c * k * k;
    patches.zero_resize(n * oh * ow, cols);
    if build_mask {
        mask.zero_resize(n * oh * ow, cols);
    }
    for ni in 0..n {
        for oy in 0..oh {
            for ox in 0..ow {
                let row = (ni * oh + oy) * ow + ox;
                // valid kx range is contiguous: ix = ox·s + kx − p ∈ [0, w)
                let kx_lo = p.saturating_sub(ox * s).min(k);
                let kx_hi = k.min((w + p).saturating_sub(ox * s));
                if kx_lo >= kx_hi {
                    continue;
                }
                let run = kx_hi - kx_lo;
                let ix0 = ox * s + kx_lo - p;
                for ky in 0..k {
                    let iy = (oy * s + ky) as isize - p as isize;
                    if iy < 0 || iy >= h as isize {
                        continue;
                    }
                    for ci in 0..c {
                        let src_col = (ci * h + iy as usize) * w + ix0;
                        let dst_col = (ci * k + ky) * k + kx_lo;
                        let chunk = bits.get_bits(ni, src_col, run);
                        patches.set_bits(row, dst_col, run, chunk);
                        if build_mask {
                            mask.set_bits(row, dst_col, run, u64::MAX);
                        }
                    }
                }
            }
        }
    }
    (oh, ow)
}

impl Layer for BoolConv2d {
    fn forward(&mut self, x: Value, train: bool) -> Value {
        let (bits, shape) = x.expect_bit(&self.name);
        assert_eq!(shape.len(), 4, "{}: need NCHW", self.name);
        let (n, c, h, w) = (shape[0], shape[1], shape[2], shape[3]);
        assert_eq!(c, self.c_in, "{}: channel mismatch", self.name);
        let (oh, ow) = self.bit_im2col(&bits, n, h, w);
        // (N·OH·OW × Cout), computed into the reused scratch buffer
        let mut s_rows = std::mem::replace(&mut self.scratch_s, Tensor::zeros(&[0]));
        self.patches.xnor_gemm_masked_into(&self.weights, &self.mask, &mut s_rows);
        let s = s_rows.rows_to_nchw(n, self.c_out, oh, ow);
        self.scratch_s = s_rows;
        // The patches buffer doubles as the backward cache; an eval-mode
        // forward overwrites it, so it also invalidates `cache_dims`.
        self.cache_dims = if train { Some((n, h, w, oh, ow)) } else { None };
        Value::F32(s)
    }

    fn backward(&mut self, z: Tensor, store: &mut ParamStore) -> Tensor {
        let (n, h, w, oh, ow) = self.cache_dims.expect("backward before (train-mode) forward");
        assert_eq!(z.shape, vec![n, self.c_out, oh, ow], "{}: bad z", self.name);
        let weight_key = self.weight_key();
        let z_rows = z.nchw_to_rows(); // (N·OH·OW × Cout)

        // Weight vote (Eq. 7): padded taps vote 0. Computed into the
        // layer's reusable scratch, then added to the store.
        let mut q_w = std::mem::replace(&mut self.scratch_qw, Tensor::zeros(&[0]));
        self.patches.backward_weight_masked_into(&z_rows, &self.mask, &mut q_w);
        store.accumulate(&weight_key, &q_w);
        self.scratch_qw = q_w;

        // Upstream signal (Eq. 8): scatter the patch-level signal back to
        // input positions. Padded lanes are dropped by col2im geometry —
        // the same masking, expressed spatially.
        let mut g_cols = std::mem::replace(&mut self.scratch_gcols, Tensor::zeros(&[0]));
        self.weights.backward_input_into(&z_rows, &mut g_cols); // (N·OH·OW × C·k·k)
        let mut g_x = g_cols.col2im(n, self.c_in, h, w, self.k, self.stride, self.pad);
        self.scratch_gcols = g_cols;
        if self.bool_bprop {
            g_x = g_x.sign_pm1();
        }
        g_x
    }

    fn params(&mut self) -> Vec<ParamRef<'_>> {
        let name = self.weight_key();
        vec![ParamRef::Bool { name, bits: &mut self.weights }]
    }

    fn name(&self) -> String {
        self.name.clone()
    }

    fn describe(&self) -> Option<Vec<LayerDesc>> {
        Some(vec![LayerDesc::BoolConv2d {
            name: self.name.clone(),
            c_in: self.c_in,
            c_out: self.c_out,
            k: self.k,
            stride: self.stride,
            pad: self.pad,
        }])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Dense reference conv in the embedded domain with 𝕄-zero padding.
    fn ref_conv(x: &Tensor, wbits: &BitMatrix, c_out: usize, k: usize, s: usize, p: usize) -> Tensor {
        let cols = x.im2col(k, s, p); // zero padding == e(0)
        let w = wbits.to_pm1();
        let (n, _c, h, wd) = x.dims4();
        let oh = (h + 2 * p - k) / s + 1;
        let ow = (wd + 2 * p - k) / s + 1;
        cols.matmul_bt(&w).rows_to_nchw(n, c_out, oh, ow)
    }

    #[test]
    fn forward_matches_dense_embedded_conv() {
        let mut rng = Rng::new(1);
        for (s, p) in [(1, 1), (1, 0), (2, 1)] {
            let mut conv = BoolConv2d::new("bc", 3, 5, 3, s, p, &mut rng);
            let x = Tensor::rand_pm1(&[2, 3, 8, 8], &mut rng);
            let out = conv.forward(Value::bit_from_pm1(&x), true).expect_f32("t");
            let want = ref_conv(&x, &conv.weights, 5, 3, s, p);
            assert_eq!(out.max_abs_diff(&want), 0.0, "s={s} p={p}");
        }
    }

    #[test]
    fn backward_weight_vote_matches_dense() {
        let mut rng = Rng::new(2);
        let mut conv = BoolConv2d::new("bc", 2, 4, 3, 1, 1, &mut rng);
        let mut store = ParamStore::new();
        let x = Tensor::rand_pm1(&[2, 2, 6, 6], &mut rng);
        let _ = conv.forward(Value::bit_from_pm1(&x), true);
        let z = Tensor::randn(&[2, 4, 6, 6], 1.0, &mut rng);
        let _ = conv.backward(z.clone(), &mut store);
        // dense: q_w = z_rowsᵀ @ cols (cols with 0 at padded taps)
        let cols = x.im2col(3, 1, 1);
        let q_ref = z.nchw_to_rows().matmul_at(&cols);
        assert!(store.grad("bc.weight").unwrap().max_abs_diff(&q_ref) < 1e-3);
    }

    #[test]
    fn backward_input_matches_dense() {
        let mut rng = Rng::new(3);
        let mut conv = BoolConv2d::new("bc", 2, 3, 3, 1, 1, &mut rng);
        let mut store = ParamStore::new();
        let x = Tensor::rand_pm1(&[1, 2, 5, 5], &mut rng);
        let _ = conv.forward(Value::bit_from_pm1(&x), true);
        let z = Tensor::randn(&[1, 3, 5, 5], 1.0, &mut rng);
        let g = conv.backward(z.clone(), &mut store);
        let g_cols = z.nchw_to_rows().matmul(&conv.weights.to_pm1());
        let g_ref = g_cols.col2im(1, 2, 5, 5, 3, 1, 1);
        assert!(g.max_abs_diff(&g_ref) < 1e-3);
    }

    /// Buffer-reuse regression: alternating input geometries must keep
    /// rebuilding/borrowing the right validity mask and reshaped scratch
    /// buffers — every forward equals a fresh layer's forward exactly.
    #[test]
    fn geometry_switches_keep_reused_buffers_correct() {
        let mut rng = Rng::new(7);
        let mut conv = BoolConv2d::new("bc", 2, 3, 3, 1, 1, &mut rng);
        let weights = conv.weights.clone();
        let shapes: [[usize; 4]; 4] = [[2, 2, 8, 8], [1, 2, 5, 5], [2, 2, 8, 8], [3, 2, 6, 6]];
        for (step, shp) in shapes.iter().enumerate() {
            let x = Tensor::rand_pm1(&[shp[0], shp[1], shp[2], shp[3]], &mut rng);
            let out = conv.forward(Value::bit_from_pm1(&x), true).expect_f32("t");
            let want = ref_conv(&x, &weights, 3, 3, 1, 1);
            assert_eq!(out.max_abs_diff(&want), 0.0, "step {step} shape {shp:?}");
        }
    }

    /// Backward after an eval-mode forward must panic (the eval forward
    /// overwrote the shared patches buffer), not silently mis-vote.
    #[test]
    #[should_panic(expected = "backward before")]
    fn backward_after_eval_forward_panics() {
        let mut rng = Rng::new(8);
        let mut conv = BoolConv2d::new("bc", 1, 2, 3, 1, 1, &mut rng);
        let mut store = ParamStore::new();
        let x = Tensor::rand_pm1(&[1, 1, 5, 5], &mut rng);
        let _ = conv.forward(Value::bit_from_pm1(&x), false);
        let z = Tensor::randn(&[1, 2, 5, 5], 1.0, &mut rng);
        let _ = conv.backward(z, &mut store);
    }

    #[test]
    fn strided_shapes() {
        let mut rng = Rng::new(4);
        let mut conv = BoolConv2d::new("bc", 3, 8, 3, 2, 1, &mut rng);
        let x = Tensor::rand_pm1(&[2, 3, 8, 8], &mut rng);
        let out = conv.forward(Value::bit_from_pm1(&x), false).expect_f32("t");
        assert_eq!(out.shape, vec![2, 8, 4, 4]);
    }

    #[test]
    fn preactivation_range_respects_valid_fanin() {
        // Interior positions see full fan-in; corners see fewer valid taps.
        let mut rng = Rng::new(5);
        let mut conv = BoolConv2d::new("bc", 1, 1, 3, 1, 1, &mut rng);
        let x = Tensor::rand_pm1(&[1, 1, 4, 4], &mut rng);
        let out = conv.forward(Value::bit_from_pm1(&x), false).expect_f32("t");
        // corner has 4 valid taps → |s| ≤ 4; interior ≤ 9
        assert!(out.data[0].abs() <= 4.0);
        let interior = out.data[1 * 4 + 1]; // position (0,0,1,1)
        assert!(interior.abs() <= 9.0);
    }
}
