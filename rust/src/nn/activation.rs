//! Activations: the paper's threshold Boolean activation (§3.1) with the
//! Appendix C backprop re-weighting, input binarization, and plain ReLU
//! for FP baselines.

use super::{Layer, LayerDesc, ParamStore, Value};
use crate::tensor::Tensor;

/// Backward re-weighting through the step activation (Appendix C.1).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum BackwardScale {
    /// Straight-through: pass z unchanged.
    Identity,
    /// z · tanh'(α·(s−τ)) with α = π/(2√(3m)) (Eq. 24) — the paper's
    /// choice; m is the layer fan-in (pre-activation range).
    TanhPrime { fanin: usize },
    /// z · (1+|s−τ|)⁻² — an alternative inverse-square window mentioned in
    /// Appendix C.1, kept for the ablation benches.
    InvSquare,
    /// z · exp(−|s−τ|) — ditto.
    ExpDecay,
}

impl BackwardScale {
    /// α of Eq. (24).
    pub fn alpha(fanin: usize) -> f32 {
        std::f32::consts::PI / (2.0 * (3.0 * fanin as f32).sqrt())
    }

    fn weight(&self, delta: f32) -> f32 {
        match *self {
            BackwardScale::Identity => 1.0,
            BackwardScale::TanhPrime { fanin } => {
                let t = (Self::alpha(fanin) * delta).tanh();
                1.0 - t * t
            }
            BackwardScale::InvSquare => {
                let d = 1.0 + delta.abs();
                1.0 / (d * d)
            }
            BackwardScale::ExpDecay => (-delta.abs()).exp(),
        }
    }
}

/// The forward Boolean activation of §3.1: y = T iff s ≥ τ.
///
/// Output is bit-packed (`Value::Bit`); the backward applies the chosen
/// [`BackwardScale`] window to the downstream signal — the variation of a
/// step function is re-weighted by proximity to the threshold, which is
/// the Appendix C regularization that makes deep Boolean training stable.
pub struct ThresholdAct {
    pub tau: f32,
    pub scale: BackwardScale,
    /// Centre the pre-activation at its batch mean before thresholding
    /// (running mean at eval). This is the paper's "0-centered" variant
    /// (code sample, Algorithm 4) — essential after MaxPool, whose max of
    /// integer counts is biased positive and would otherwise saturate the
    /// Boolean activations to T.
    pub center: bool,
    running_mean: Vec<f32>,
    momentum: f32,
    name: String,
    cache_s: Option<Tensor>,
    cache_shift: f32,
}

impl ThresholdAct {
    pub fn new(name: &str, tau: f32, scale: BackwardScale) -> Self {
        ThresholdAct {
            tau,
            scale,
            center: false,
            running_mean: vec![0.0],
            momentum: 0.1,
            name: name.to_string(),
            cache_s: None,
            cache_shift: 0.0,
        }
    }

    pub fn centered(mut self) -> Self {
        self.center = true;
        self
    }
}

impl Layer for ThresholdAct {
    fn forward(&mut self, x: Value, train: bool) -> Value {
        let s = x.expect_f32(&self.name);
        let shift = if self.center {
            if train {
                let m = s.mean();
                self.running_mean[0] =
                    (1.0 - self.momentum) * self.running_mean[0] + self.momentum * m;
                m
            } else {
                self.running_mean[0]
            }
        } else {
            0.0
        };
        let thr = self.tau + shift;
        let y = s.map(|v| if v >= thr { 1.0 } else { -1.0 });
        if train {
            self.cache_s = Some(s);
            self.cache_shift = shift;
        }
        Value::bit_from_pm1(&y)
    }

    fn backward(&mut self, z: Tensor, _store: &mut ParamStore) -> Tensor {
        let s = self.cache_s.as_ref().expect("backward before forward");
        assert_eq!(z.shape, s.shape, "{}: z shape", self.name);
        let thr = self.tau + self.cache_shift;
        let scale = self.scale;
        Tensor {
            shape: z.shape.clone(),
            data: z
                .data
                .iter()
                .zip(&s.data)
                .map(|(&zv, &sv)| zv * scale.weight(sv - thr))
                .collect(),
        }
    }

    fn name(&self) -> String {
        self.name.clone()
    }

    fn buffers(&mut self) -> Vec<(String, &mut Vec<f32>)> {
        if self.center {
            vec![(format!("{}.running_mean", self.name), &mut self.running_mean)]
        } else {
            Vec::new()
        }
    }

    fn describe(&self) -> Option<Vec<LayerDesc>> {
        Some(vec![LayerDesc::ThresholdAct {
            name: self.name.clone(),
            tau: self.tau,
            centered: self.center,
        }])
    }
}

/// Input binarization: real input → ±1 bits (sign). Used at the front of
/// fully-Boolean models; the backward passes the signal through unchanged
/// (there is nothing upstream to optimize).
pub struct Binarize {
    name: String,
}

impl Binarize {
    pub fn new(name: &str) -> Self {
        Binarize { name: name.to_string() }
    }
}

impl Layer for Binarize {
    fn forward(&mut self, x: Value, _train: bool) -> Value {
        let t = x.to_f32();
        Value::bit_from_pm1(&t.sign_pm1())
    }

    fn backward(&mut self, z: Tensor, _store: &mut ParamStore) -> Tensor {
        z
    }

    fn name(&self) -> String {
        self.name.clone()
    }

    fn describe(&self) -> Option<Vec<LayerDesc>> {
        Some(vec![LayerDesc::Binarize { name: self.name.clone() }])
    }
}

/// Plain ReLU for the FP baselines and FP heads.
pub struct ReLU {
    name: String,
    cache_mask: Option<Vec<bool>>,
}

impl ReLU {
    pub fn new(name: &str) -> Self {
        ReLU { name: name.to_string(), cache_mask: None }
    }
}

impl Layer for ReLU {
    fn forward(&mut self, x: Value, train: bool) -> Value {
        let t = x.expect_f32(&self.name);
        if train {
            self.cache_mask = Some(t.data.iter().map(|&v| v > 0.0).collect());
        }
        Value::F32(t.map(|v| v.max(0.0)))
    }

    fn backward(&mut self, z: Tensor, _store: &mut ParamStore) -> Tensor {
        let mask = self.cache_mask.as_ref().expect("backward before forward");
        assert_eq!(mask.len(), z.len());
        Tensor {
            shape: z.shape.clone(),
            data: z.data.iter().zip(mask).map(|(&v, &m)| if m { v } else { 0.0 }).collect(),
        }
    }

    fn name(&self) -> String {
        self.name.clone()
    }

    fn describe(&self) -> Option<Vec<LayerDesc>> {
        Some(vec![LayerDesc::ReLU { name: self.name.clone() }])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn threshold_forward_signs() {
        let mut a = ThresholdAct::new("act", 0.0, BackwardScale::Identity);
        let s = Tensor::from_vec(&[1, 4], vec![-2.0, 0.0, 0.5, -0.1]);
        let y = a.forward(Value::F32(s), true).to_f32();
        assert_eq!(y.data, vec![-1.0, 1.0, 1.0, -1.0]);
    }

    #[test]
    fn tanh_prime_attenuates_far_from_threshold() {
        let fanin = 256;
        let mut a = ThresholdAct::new("act", 0.0, BackwardScale::TanhPrime { fanin });
        let s = Tensor::from_vec(&[1, 3], vec![0.0, 20.0, 200.0]);
        let _ = a.forward(Value::F32(s), true);
        let g = a.backward(Tensor::full(&[1, 3], 1.0), &mut ParamStore::new());
        assert!((g.data[0] - 1.0).abs() < 1e-6, "at threshold, full signal");
        assert!(g.data[1] < g.data[0] && g.data[2] < g.data[1], "{:?}", g.data);
    }

    #[test]
    fn alpha_matches_eq_24() {
        // α = π / (2 √(3m))
        let a = BackwardScale::alpha(27);
        assert!((a - std::f32::consts::PI / 18.0).abs() < 1e-6);
    }

    #[test]
    fn all_scales_are_unit_at_threshold_and_decay() {
        for scale in [
            BackwardScale::TanhPrime { fanin: 64 },
            BackwardScale::InvSquare,
            BackwardScale::ExpDecay,
        ] {
            assert!((scale.weight(0.0) - 1.0).abs() < 1e-6, "{scale:?}");
            assert!(scale.weight(5.0) < 1.0);
            assert!(scale.weight(10.0) < scale.weight(5.0));
            // symmetric window
            assert!((scale.weight(-3.0) - scale.weight(3.0)).abs() < 1e-6);
        }
    }

    #[test]
    fn relu_backward_masks() {
        let mut r = ReLU::new("relu");
        let x = Tensor::from_vec(&[1, 4], vec![-1.0, 2.0, -3.0, 4.0]);
        let y = r.forward(Value::F32(x), true).expect_f32("t");
        assert_eq!(y.data, vec![0.0, 2.0, 0.0, 4.0]);
        let g = r.backward(Tensor::full(&[1, 4], 1.0), &mut ParamStore::new());
        assert_eq!(g.data, vec![0.0, 1.0, 0.0, 1.0]);
    }

    #[test]
    fn binarize_outputs_bits() {
        let mut rng = Rng::new(1);
        let mut b = Binarize::new("bin");
        let x = Tensor::randn(&[2, 8], 1.0, &mut rng);
        let y = b.forward(Value::F32(x.clone()), true);
        assert!(y.is_bit());
        assert_eq!(y.to_f32(), x.sign_pm1());
    }
}
