//! Per-layer energy: shapes, compute energy (Appendix E.2) and the glue
//! that combines tiling + access counts + per-level costs into joules
//! (Eqs. 51–54).

use super::dataflow::{access_counts_backward, access_counts_forward};
use super::hardware::Hardware;
use super::methods::Bitwidths;
use super::tiling::search_tiling;

/// Convolution shape parameters (Table 16). A linear layer is the 1×1
/// special case (h = w = k = 1, c = fan-in, m = fan-out).
#[derive(Debug, Clone, Copy)]
pub struct ConvShape {
    /// Batch size N.
    pub n: usize,
    /// Input channels C.
    pub c: usize,
    /// Output channels M.
    pub m: usize,
    /// Input plane H^I × W^I.
    pub h: usize,
    pub w: usize,
    /// Filter size H^F = W^F = k.
    pub k: usize,
    pub stride: usize,
    pub pad: usize,
}

impl ConvShape {
    pub fn linear(n: usize, fan_in: usize, fan_out: usize) -> Self {
        ConvShape { n, c: fan_in, m: fan_out, h: 1, w: 1, k: 1, stride: 1, pad: 0 }
    }

    pub fn out_hw(&self) -> (usize, usize) {
        (
            (self.h + 2 * self.pad - self.k) / self.stride + 1,
            (self.w + 2 * self.pad - self.k) / self.stride + 1,
        )
    }

    /// MAC count of the forward convolution.
    pub fn macs(&self) -> f64 {
        let (oh, ow) = self.out_hw();
        self.n as f64
            * self.m as f64
            * self.c as f64
            * oh as f64
            * ow as f64
            * (self.k * self.k) as f64
    }

    pub fn ifmap_elems(&self) -> f64 {
        (self.n * self.c * self.h * self.w) as f64
    }

    pub fn filter_elems(&self) -> f64 {
        (self.m * self.c * self.k * self.k) as f64
    }

    pub fn ofmap_elems(&self) -> f64 {
        let (oh, ow) = self.out_hw();
        (self.n * self.m * oh * ow) as f64
    }
}

/// Which pass is being costed.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    Forward,
    /// Backward = ∂Loss/∂I (Eq. 54) + ∂Loss/∂F (Eq. 53), both convs.
    Backward,
}

/// Energy result in picojoules, split by source.
#[derive(Debug, Clone, Copy, Default)]
pub struct EnergyBreakdown {
    pub compute_pj: f64,
    pub mem_pj: f64,
}

impl EnergyBreakdown {
    pub fn total(&self) -> f64 {
        self.compute_pj + self.mem_pj
    }

    pub fn add(&mut self, other: EnergyBreakdown) {
        self.compute_pj += other.compute_pj;
        self.mem_pj += other.mem_pj;
    }
}

/// Compute energy for `macs` multiply-accumulates at integer bitwidth `n`
/// (Appendix E.2: ADD INT-n costs (2n−1) logic ops; we cost an INT-n MAC
/// at (2n−1)/(2·32−1) of an FP32 MAC) or as pure Boolean logic
/// (XNOR + count = 2 logic-lane ops per pair).
fn compute_energy(macs: f64, bits: u32, logic: bool, hw: &Hardware) -> f64 {
    if logic {
        2.0 * macs * hw.pj_per_logic_op
    } else if bits >= 32 {
        macs * hw.pj_per_mac_fp32
    } else {
        macs * hw.pj_per_mac_fp32 * ((2 * bits - 1) as f64 / 63.0)
    }
}

/// Memory energy of one conv pass: per-stream access-count cascade
/// (Eq. 51) + the single output write (Eq. 52 with n_i = 1).
fn mem_energy(
    shape: &ConvShape,
    hw: &Hardware,
    bits_i: u32,
    bits_f: u32,
    bits_o: u32,
    backward: bool,
) -> f64 {
    let tiling = search_tiling(shape, hw, bits_i, bits_f);
    let ac = if backward {
        access_counts_backward(shape, &tiling)
    } else {
        access_counts_forward(shape, &tiling)
    };
    let bytes_i = shape.ifmap_elems() * bits_i as f64 / 8.0;
    let bytes_f = shape.filter_elems() * bits_f as f64 / 8.0;
    let bytes_o = shape.ofmap_elems() * bits_o as f64 / 8.0;
    let mut e = 0.0;
    // Eq. (51): cascade of products down the hierarchy.
    let mut prod_i = 1.0;
    let mut prod_f = 1.0;
    for (lvl, mem) in hw.levels.iter().enumerate() {
        prod_i *= ac.i[lvl];
        prod_f *= ac.f[lvl];
        e += bytes_i * prod_i * mem.pj_per_byte;
        e += bytes_f * prod_f * mem.pj_per_byte;
    }
    // Eq. (52) with n_i = 1 at every level: one write of O to DRAM plus
    // one pass through each level.
    for mem in &hw.levels {
        e += bytes_o * mem.pj_per_byte;
    }
    e
}

/// Energy of one convolution layer for one pass of one batch.
pub fn conv_energy(
    shape: &ConvShape,
    hw: &Hardware,
    bits: &Bitwidths,
    phase: Phase,
) -> EnergyBreakdown {
    match phase {
        Phase::Forward => EnergyBreakdown {
            compute_pj: compute_energy(
                shape.macs(),
                bits.weight_fwd.max(bits.act),
                bits.logic_forward,
                hw,
            ),
            mem_pj: mem_energy(shape, hw, bits.act, bits.weight_fwd, bits.act, false),
        },
        Phase::Backward => {
            // ∂Loss/∂I = conv(rot(F), ∂Loss/∂O)  (Eq. 54): streams dO + F.
            let e_di = EnergyBreakdown {
                compute_pj: compute_energy(
                    shape.macs(),
                    bits.grad.max(bits.weight_fwd),
                    false, // gradient arithmetic is numeric (INT16/FP), not logic
                    hw,
                ),
                mem_pj: mem_energy(shape, hw, bits.grad, bits.weight_fwd, bits.grad, true),
            };
            // ∂Loss/∂F = conv(I, ∂Loss/∂O)  (Eq. 53): streams I + dO.
            let e_dw = EnergyBreakdown {
                compute_pj: compute_energy(shape.macs(), bits.grad.max(bits.act), false, hw),
                mem_pj: mem_energy(shape, hw, bits.act, bits.grad, bits.grad, true),
            };
            let mut e = e_di;
            e.add(e_dw);
            e
        }
    }
}

/// Word-access comparison of a LUT-folded Boolean layer
/// (`PackedOp::Lut`, DESIGN.md §LUT-Folding) against the XNOR+popcount
/// kernel it replaces, for one forward batch. Counts are 64-bit word
/// accesses — the unit the serving kernels actually move — so the delta
/// is the memory-traffic side of the NullaNet fold, independent of the
/// per-level pJ cascade above.
#[derive(Debug, Clone, Copy)]
pub struct LutCost {
    /// Per-neuron fan-in K.
    pub fanin: usize,
    /// Output neurons (linear rows or conv channels).
    pub n_out: usize,
    /// 64-lane evaluation groups (⌈lanes/64⌉ per image/batch tile).
    pub groups: usize,
    /// Word accesses of the popcount path across all groups.
    pub popcount_accesses: f64,
    /// Word accesses of the bitsliced table path across all groups.
    pub lut_accesses: f64,
    /// Truth-table storage the fold carries (2^K bits × n_out).
    pub table_bytes: usize,
}

impl LutCost {
    /// Access reduction in percent (negative when the fold loses).
    pub fn saving_pct(&self) -> f64 {
        100.0 * (1.0 - self.lut_accesses / self.popcount_accesses)
    }
}

/// Access-count model of a fan-in-K layer over `lanes` evaluation lanes
/// (batch rows for a linear fold, spatial positions per image for a
/// conv fold). Per 64-lane group:
///
/// * popcount: every neuron streams the 64 packed input rows plus its
///   weight row (`wpr = ⌈K/64⌉` words each) and writes one output word
///   → `m·(64+1)·wpr + m`.
/// * LUT: the K bit-columns are gathered once (64 reads each, shared by
///   all neurons), each neuron streams its `⌈2^K/64⌉`-word table and
///   writes one output word → `64·K + m·(tw + 1)`.
///
/// The gather term is neuron-independent, so the fold wins when m is
/// large relative to K and loses for small m at high K — the "when it
/// loses" boundary documented in DESIGN.md.
pub fn lut_layer_cost(fanin: usize, n_out: usize, lanes: usize) -> LutCost {
    assert!(fanin >= 1 && lanes >= 1, "lut cost needs fanin, lanes >= 1");
    let groups = lanes.div_ceil(64);
    let wpr = fanin.div_ceil(64);
    let tw = (1usize << fanin).div_ceil(64);
    let m = n_out as f64;
    let popcount = m * (64 + 1) as f64 * wpr as f64 + m;
    let lut = (64 * fanin) as f64 + m * (tw + 1) as f64;
    LutCost {
        fanin,
        n_out,
        groups,
        popcount_accesses: popcount * groups as f64,
        lut_accesses: lut * groups as f64,
        table_bytes: n_out * tw * 8,
    }
}

/// Energy of a linear layer (1×1-conv special case).
pub fn linear_energy(
    n: usize,
    fan_in: usize,
    fan_out: usize,
    hw: &Hardware,
    bits: &Bitwidths,
    phase: Phase,
) -> EnergyBreakdown {
    conv_energy(&ConvShape::linear(n, fan_in, fan_out), hw, bits, phase)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::energy::hardware::{ascend, v100};
    use crate::energy::methods::{method_bitwidths, Method};

    fn shape() -> ConvShape {
        ConvShape { n: 32, c: 128, m: 128, h: 16, w: 16, k: 3, stride: 1, pad: 1 }
    }

    #[test]
    fn macs_formula() {
        let s = ConvShape { n: 2, c: 3, m: 4, h: 8, w: 8, k: 3, stride: 1, pad: 1 };
        // OH=OW=8 → 2·4·3·8·8·9
        assert_eq!(s.macs(), (2 * 4 * 3 * 8 * 8 * 9) as f64);
    }

    #[test]
    fn bold_forward_is_much_cheaper_than_fp() {
        for hw in [ascend(), v100()] {
            let fp = conv_energy(&shape(), &hw, &method_bitwidths(Method::Fp32), Phase::Forward);
            let bold = conv_energy(&shape(), &hw, &method_bitwidths(Method::Bold), Phase::Forward);
            assert!(
                bold.total() < fp.total() / 8.0,
                "{}: bold {} vs fp {}",
                hw.name,
                bold.total(),
                fp.total()
            );
        }
    }

    #[test]
    fn backward_costs_more_than_forward() {
        let hw = v100();
        let bits = method_bitwidths(Method::Fp32);
        let f = conv_energy(&shape(), &hw, &bits, Phase::Forward);
        let b = conv_energy(&shape(), &hw, &bits, Phase::Backward);
        assert!(b.total() > f.total(), "two convs in backward");
    }

    #[test]
    fn binarynet_training_not_much_cheaper_than_fp() {
        // the paper's point: latent-weight BNN *training* stays FP-bound
        let hw = v100();
        let fp = conv_energy(&shape(), &hw, &method_bitwidths(Method::Fp32), Phase::Backward);
        let bnn =
            conv_energy(&shape(), &hw, &method_bitwidths(Method::BinaryNet), Phase::Backward);
        // FP32 gradients keep the BNN backward within a small factor of FP
        // (Table 2 reports ~44% for the full iteration incl. optimizer).
        assert!(bnn.total() > fp.total() * 0.2, "bnn bwd {} vs fp {}", bnn.total(), fp.total());
    }

    #[test]
    fn lut_fold_cuts_accesses_for_a_converted_archetype() {
        // the acceptance archetype: fan-in 9, 70 neurons, 130-row batch
        // (the packed_graph LUT parity fixture) must be strictly cheaper
        let c = lut_layer_cost(9, 70, 130);
        assert!(
            c.lut_accesses < c.popcount_accesses,
            "lut {} vs popcount {}",
            c.lut_accesses,
            c.popcount_accesses
        );
        assert!(c.saving_pct() > 0.0);
        assert_eq!(c.groups, 3);
        assert_eq!(c.table_bytes, 70 * 8 * 8); // 2^9 bits = 8 words per neuron
    }

    #[test]
    fn lut_fold_loses_for_few_neurons_at_high_fanin() {
        // the documented break-even: at K=10 a 4-neuron layer pays more
        // for the column gather + 16-word tables than popcount ever did
        let c = lut_layer_cost(10, 4, 64);
        assert!(
            c.lut_accesses > c.popcount_accesses,
            "lut {} vs popcount {}",
            c.lut_accesses,
            c.popcount_accesses
        );
        assert!(c.saving_pct() < 0.0);
    }

    #[test]
    fn linear_is_conv_special_case() {
        let hw = ascend();
        let bits = method_bitwidths(Method::Fp32);
        let a = linear_energy(16, 1024, 10, &hw, &bits, Phase::Forward);
        let b = conv_energy(&ConvShape::linear(16, 1024, 10), &hw, &bits, Phase::Forward);
        assert_eq!(a.total(), b.total());
    }
}
