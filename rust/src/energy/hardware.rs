//! Hardware specifications: Ascend (Table 14) and Tesla V100 (Table 15).

/// One memory level: capacity (bytes; `usize::MAX` = unbounded DRAM) and
/// energy cost per byte moved (picojoules).
#[derive(Debug, Clone, Copy)]
pub struct MemLevel {
    pub name: &'static str,
    pub capacity: usize,
    pub pj_per_byte: f64,
}

/// A hardware target for the Appendix E model. Levels are ordered from
/// DRAM (index 0) down to the level nearest the compute unit.
#[derive(Debug, Clone)]
pub struct Hardware {
    pub name: &'static str,
    pub levels: Vec<MemLevel>,
    /// Energy per FP32 MAC at the compute unit (pJ).
    pub pj_per_mac_fp32: f64,
    /// Energy per elementary Boolean logic op (XNOR/popcount lane) (pJ).
    pub pj_per_logic_op: f64,
}

impl Hardware {
    pub fn dram(&self) -> &MemLevel {
        &self.levels[0]
    }

    pub fn n_levels(&self) -> usize {
        self.levels.len()
    }
}

/// Ascend core (Table 14). Energy efficiency in GBPS/mW converts to
/// pJ/byte as 1/EE (1 GBPS/mW == 1 byte/nJ ⇒ cost = 1/EE nJ/byte… the
/// model only needs *relative* numbers, so we use pJ/byte = 1000/EE with
/// EE in GBPS/mW, keeping DRAM ≫ L2 > L1 ≫ L0 exactly as published):
/// DRAM 0.02 → 50 000, L2 0.2 → 5 000, L1 0.4 → 2 500,
/// L0-A 4.9 → 204, L0-B 3.5 → 286, L0-C 5.4 → 185 (we fold the three L0
/// buffers into per-stream costs). Capacities from Table 14.
pub fn ascend() -> Hardware {
    Hardware {
        name: "Ascend",
        levels: vec![
            MemLevel { name: "DRAM", capacity: usize::MAX, pj_per_byte: 50_000.0 / 1000.0 },
            MemLevel { name: "L2", capacity: 8192 * 1024, pj_per_byte: 5_000.0 / 1000.0 },
            MemLevel { name: "L1", capacity: 1024 * 1024, pj_per_byte: 2_500.0 / 1000.0 },
            // L0: average of the L0-A/B/C efficiencies (4.9/3.5/5.4 → 4.6)
            MemLevel { name: "L0", capacity: 64 * 1024, pj_per_byte: 1.0 / 4.6 },
        ],
        // compute efficiency 1.7 TOPS/W (Appendix E.2) ⇒ 1/1.7 pJ per op;
        // an FP32 MAC is counted as one "op" of that rate on the cube.
        pj_per_mac_fp32: 1.0 / 1.7,
        // a Boolean logic op is a single gate-level op; on the same 1.7
        // TOPS/W fabric with 1-bit lanes we charge 1/32 of a 32-bit op.
        pj_per_logic_op: 1.0 / 1.7 / 32.0,
    }
}

/// Tesla V100 normalized model (Table 15): costs relative to one MAC at
/// the ALU — DRAM 200×, L2 6×, L1 2×, RF 1×. We set the MAC to 1.0 "unit"
/// and scale per-byte costs by assuming the published ratios are for
/// 32-bit words (4 bytes).
pub fn v100() -> Hardware {
    let mac = 1.0;
    Hardware {
        name: "Tesla V100",
        levels: vec![
            MemLevel { name: "DRAM", capacity: usize::MAX, pj_per_byte: 200.0 * mac / 4.0 },
            MemLevel { name: "L2", capacity: 6 * 1024 * 1024, pj_per_byte: 6.0 * mac / 4.0 },
            MemLevel { name: "L1", capacity: 64 * 1024, pj_per_byte: 2.0 * mac / 4.0 },
            MemLevel { name: "RF", capacity: 16 * 1024, pj_per_byte: 1.0 * mac / 4.0 },
        ],
        pj_per_mac_fp32: mac,
        // 1-bit logic lane ≈ 1/32 of a 32-bit ALU op (Appendix E.2's
        // (2n−1)-gates rule applied at n=1 relative to FP32 ALU width).
        pj_per_logic_op: mac / 32.0,
    }
}

/// Static Ascend instance accessor (convenience).
pub static ASCEND: fn() -> Hardware = ascend;
/// Static V100 instance accessor (convenience).
pub static V100: fn() -> Hardware = v100;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cost_hierarchy_is_monotone() {
        for hw in [ascend(), v100()] {
            for pair in hw.levels.windows(2) {
                assert!(
                    pair[0].pj_per_byte > pair[1].pj_per_byte,
                    "{}: outer levels must cost more ({} vs {})",
                    hw.name,
                    pair[0].name,
                    pair[1].name
                );
            }
        }
    }

    #[test]
    fn dram_ratio_matches_tables() {
        // Table 14: DRAM/L2 = 0.2/0.02 = 10×; Table 15: DRAM/L2 = 200/6.
        let a = ascend();
        assert!((a.levels[0].pj_per_byte / a.levels[1].pj_per_byte - 10.0).abs() < 1e-6);
        let v = v100();
        assert!((v.levels[0].pj_per_byte / v.levels[1].pj_per_byte - 200.0 / 6.0).abs() < 1e-6);
    }

    #[test]
    fn logic_op_is_much_cheaper_than_mac() {
        for hw in [ascend(), v100()] {
            assert!(hw.pj_per_logic_op * 8.0 < hw.pj_per_mac_fp32, "{}", hw.name);
        }
    }
}
