//! Analytic energy model — Appendix E of the paper, implemented in full.
//!
//! Energy = compute energy + memory energy.
//! * Compute: #arithmetic ops × per-op cost, with ADD INT-n costed at
//!   (2n−1) logic-gate ops (Appendix E.2) and FP32 MACs at the hardware
//!   MAC cost.
//! * Memory: data movement through the memory hierarchy — tiling search
//!   (Algorithm 9) chooses per-level tile sizes under capacity
//!   constraints; the weight-stationary/input-cycling movement of
//!   Algorithm 10 yields the access counts of Tables 18/19; Eqs. (51)–(52)
//!   convert access counts × per-level cost into energy, for the forward
//!   AND the two backward convolutions (Eqs. 53–54).
//!
//! Two hardware targets are encoded: Ascend (Table 14 energy-efficiency
//! per level) and an Nvidia V100-normalized model (Table 15). Per-method
//! bitwidths (B⊕LD 1/1/16, BNN latent-weight FP, FP32 baseline) determine
//! the bytes moved and the arithmetic cost — regenerating the Cons.(%)
//! columns of Tables 2/5 and Fig. 1.
//!
//! The serve-path LUT fold (DESIGN.md §LUT-Folding) has its own
//! word-access model ([`lut_layer_cost`]): it compares the bitsliced
//! truth-table kernel against the XNOR+popcount GEMM it replaces in the
//! unit the kernels actually move (64-bit words), surfaced by
//! `bold energy`.

mod dataflow;
mod hardware;
mod layer_cost;
mod methods;
mod network;
mod tiling;

pub use dataflow::{access_counts_backward, access_counts_forward, AccessCounts};
pub use hardware::{Hardware, MemLevel, ASCEND, V100};
pub use layer_cost::{
    conv_energy, linear_energy, lut_layer_cost, ConvShape, EnergyBreakdown, LutCost, Phase,
};
pub use methods::{method_bitwidths, Bitwidths, Method};
pub use network::{network_energy, resnet18_shapes, vgg_small_shapes, NetworkEnergy};
pub use tiling::{search_tiling, Tiling};
