//! Access-count formulas (Tables 18/19, data movement of Algorithm 10).
//!
//! For the forward convolution with the weight-stationary / input-cycling
//! movement, each stream's access count *per element* at each level forms
//! a cascade (Eq. 51): an element is fetched `n₃` times from DRAM, each
//! of those appears `n₂` times at L2, etc.

use super::layer_cost::ConvShape;
use super::tiling::{out_dim, Tiling};

/// Per-level access multipliers for the three streams.
/// Index 0 = DRAM (Table 18's "DRAM (L3)" column), increasing inward.
#[derive(Debug, Clone)]
pub struct AccessCounts {
    pub i: Vec<f64>,
    pub f: Vec<f64>,
    pub o: Vec<f64>,
}

fn alpha(in_dim: usize, k: usize, stride: usize) -> f64 {
    out_dim(in_dim, k, stride) as f64 / in_dim as f64
}

/// Table 18: forward access counts given the chosen tiling.
pub fn access_counts_forward(shape: &ConvShape, tiling: &Tiling) -> AccessCounts {
    let levels = tiling.tiles.len() + 1;
    let mut i = Vec::with_capacity(levels);
    let mut f = Vec::with_capacity(levels);
    let mut o = Vec::with_capacity(levels);
    let k = shape.k;
    let s = shape.stride;
    for lvl in 0..levels {
        if lvl + 1 < levels {
            let cur = tiling.at(shape, lvl);
            let nxt = tiling.at(shape, lvl + 1);
            // IFMAPS: re-read once per child filter-block, inflated by the
            // halo overlap ratio α_cur/α_next (Table 18 row I).
            let n_i = (cur.m as f64 / nxt.m as f64).ceil()
                * (alpha(cur.h, k, s) / alpha(nxt.h, k, s))
                * (alpha(cur.w, k, s) / alpha(nxt.w, k, s));
            // FILTERS: DRAM read once; below, once per (batch × spatial)
            // child block (Table 18 row F).
            let n_f = if lvl == 0 {
                1.0
            } else {
                let oh_c = out_dim(cur.h, k, s).max(1) as f64;
                let ow_c = out_dim(cur.w, k, s).max(1) as f64;
                let oh_n = out_dim(nxt.h, k, s).max(1) as f64;
                let ow_n = out_dim(nxt.w, k, s).max(1) as f64;
                (cur.n as f64 / nxt.n as f64).ceil()
                    * (oh_c / oh_n).ceil()
                    * (ow_c / ow_n).ceil()
            };
            i.push(n_i.max(1.0));
            f.push(n_f.max(1.0));
        } else {
            // innermost level (L0): convolutional reuse (Table 18 last col)
            let t = tiling.at(shape, lvl);
            let a_v = alpha(t.h, k, s);
            let a_h = alpha(t.w, k, s);
            i.push(((k * k) as f64 * a_v * a_h).max(1.0));
            f.push(1.0);
        }
        // outputs: written once per level (partial sums stay in the cube —
        // output-stationary L0, Appendix E.3.2)
        o.push(1.0);
    }
    AccessCounts { i, f, o }
}

/// Table 19: backward access counts. The backward passes are convolutions
/// too (Eqs. 53–54) with IFMAPS↔OFMAPS roles swapped; the β ratios of
/// Table 19 mirror the α ratios with output/input dims exchanged. We
/// reuse the forward machinery on the role-swapped shape.
pub fn access_counts_backward(shape: &ConvShape, tiling: &Tiling) -> AccessCounts {
    // Role swap: the "input" stream of ∂Loss/∂I is ∂Loss/∂O with the same
    // spatial extent (full conv with rotated filters, stride-1 geometry).
    let (oh, ow) = shape.out_hw();
    let swapped = ConvShape {
        n: shape.n,
        c: shape.m,
        m: shape.c,
        h: oh,
        w: ow,
        k: shape.k,
        stride: 1,
        pad: shape.k.saturating_sub(1),
    };
    access_counts_forward(&swapped, tiling)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::energy::hardware::ascend;
    use crate::energy::tiling::search_tiling;

    fn shape() -> ConvShape {
        ConvShape { n: 16, c: 64, m: 128, h: 32, w: 32, k: 3, stride: 1, pad: 1 }
    }

    #[test]
    fn filters_read_once_from_dram() {
        let hw = ascend();
        let t = search_tiling(&shape(), &hw, 32, 32);
        let ac = access_counts_forward(&shape(), &t);
        assert_eq!(ac.f[0], 1.0, "Table 18: n₃^F = 1");
    }

    #[test]
    fn ifmap_dram_reads_grow_when_l2_filter_tile_shrinks() {
        // With a tiny L2 (forcing small M₂), IFMAPS must be re-read
        // ⌈M/M₂⌉ times from DRAM.
        let mut hw = ascend();
        let t_big = search_tiling(&shape(), &hw, 32, 32);
        let ac_big = access_counts_forward(&shape(), &t_big);
        hw.levels[1].capacity = 8 * 1024; // shrink L2 to 8 KiB
        let t_small = search_tiling(&shape(), &hw, 32, 32);
        let ac_small = access_counts_forward(&shape(), &t_small);
        assert!(
            ac_small.i[0] >= ac_big.i[0],
            "smaller L2 ⇒ more DRAM refetches ({} vs {})",
            ac_small.i[0],
            ac_big.i[0]
        );
    }

    #[test]
    fn innermost_has_convolutional_reuse() {
        let hw = ascend();
        let t = search_tiling(&shape(), &hw, 32, 32);
        let ac = access_counts_forward(&shape(), &t);
        let last = *ac.i.last().unwrap();
        assert!(last >= 1.0 && last <= (shape().k * shape().k) as f64);
    }

    #[test]
    fn all_counts_at_least_one() {
        let hw = ascend();
        let t = search_tiling(&shape(), &hw, 1, 1);
        for ac in [access_counts_forward(&shape(), &t), access_counts_backward(&shape(), &t)] {
            for v in ac.i.iter().chain(&ac.f).chain(&ac.o) {
                assert!(*v >= 1.0, "{v}");
            }
        }
    }
}
