//! Network-level energy: per-layer shape lists for the paper's exact
//! architectures (VGG-SMALL on CIFAR10, ResNet18 on ImageNet) and the
//! whole-training-iteration aggregation (forward + backward + optimizer
//! update) that regenerates the Cons.(%) columns of Tables 2/5 and Fig. 1.

use super::hardware::Hardware;
use super::layer_cost::{conv_energy, ConvShape, EnergyBreakdown, Phase};
use super::methods::{method_bitwidths, Method};

/// Named layer shape.
#[derive(Debug, Clone)]
pub struct NamedShape {
    pub name: String,
    pub shape: ConvShape,
    /// First/last layers stay FP for every binarized method (§4 setup).
    pub always_fp: bool,
}

/// VGG-SMALL on 32×32 CIFAR10 (paper dims: 2×128C3-MP2-2×256C3-MP2-
/// 2×512C3-MP2-1024FC-10FC), batch `n`.
pub fn vgg_small_shapes(n: usize) -> Vec<NamedShape> {
    let conv = |name: &str, c, m, hw_| NamedShape {
        name: name.into(),
        shape: ConvShape { n, c, m, h: hw_, w: hw_, k: 3, stride: 1, pad: 1 },
        always_fp: false,
    };
    let mut v = vec![
        NamedShape { always_fp: true, ..conv("conv1a", 3, 128, 32) },
        conv("conv1b", 128, 128, 32),
        conv("conv2a", 128, 256, 16),
        conv("conv2b", 256, 256, 16),
        conv("conv3a", 256, 512, 8),
        conv("conv3b", 512, 512, 8),
    ];
    v.push(NamedShape {
        name: "fc1".into(),
        shape: ConvShape::linear(n, 512 * 4 * 4, 1024),
        always_fp: false,
    });
    v.push(NamedShape {
        name: "head".into(),
        shape: ConvShape::linear(n, 1024, 10),
        always_fp: true,
    });
    v
}

/// ResNet18 on 224×224 ImageNet with first-layer mapping dimension
/// `base` (Table 5's knob; 64 = standard).
pub fn resnet18_shapes(n: usize, base: usize) -> Vec<NamedShape> {
    let mut v = Vec::new();
    // stem: 7×7/2 conv, FP
    v.push(NamedShape {
        name: "stem".into(),
        shape: ConvShape { n, c: 3, m: base, h: 224, w: 224, k: 7, stride: 2, pad: 3 },
        always_fp: true,
    });
    // 4 stages × 2 blocks × 2 convs (+1 shortcut conv per downsampling
    // block, Block I style)
    let mut c_in = base;
    let mut hw_ = 56; // after stem/2 + maxpool/2
    for stage in 0..4 {
        let c_out = base << stage;
        for block in 0..2 {
            let stride = if stage > 0 && block == 0 { 2 } else { 1 };
            let h_in = if stride == 2 { hw_ * 2 } else { hw_ };
            if stage > 0 && block == 0 {
                hw_ = h_in / 2;
            }
            v.push(NamedShape {
                name: format!("s{stage}b{block}c1"),
                shape: ConvShape { n, c: c_in, m: c_out, h: h_in, w: h_in, k: 3, stride, pad: 1 },
                always_fp: false,
            });
            v.push(NamedShape {
                name: format!("s{stage}b{block}c2"),
                shape: ConvShape { n, c: c_out, m: c_out, h: hw_, w: hw_, k: 3, stride: 1, pad: 1 },
                always_fp: false,
            });
            if stride == 2 || c_in != c_out {
                v.push(NamedShape {
                    name: format!("s{stage}b{block}sc"),
                    shape: ConvShape {
                        n,
                        c: c_in,
                        m: c_out,
                        h: h_in,
                        w: h_in,
                        k: 3,
                        stride,
                        pad: 1,
                    },
                    always_fp: false,
                });
            }
            c_in = c_out;
        }
    }
    v.push(NamedShape {
        name: "head".into(),
        shape: ConvShape::linear(n, base * 8, 1000),
        always_fp: true,
    });
    v
}

/// Whole-network energy for one training iteration (or inference pass).
#[derive(Debug, Clone)]
pub struct NetworkEnergy {
    pub method: Method,
    pub hw_name: &'static str,
    pub per_layer_pj: Vec<(String, f64)>,
    pub compute_pj: f64,
    pub mem_pj: f64,
    /// Optimizer-state movement (latent weights, Adam moments, Boolean
    /// accumulators) — the training-only cost the paper's argument hinges
    /// on.
    pub optimizer_pj: f64,
}

impl NetworkEnergy {
    pub fn total_pj(&self) -> f64 {
        self.compute_pj + self.mem_pj + self.optimizer_pj
    }
}

/// Evaluate a network's energy for one pass.
/// `train` adds the backward pass and optimizer-state movement.
pub fn network_energy(
    shapes: &[NamedShape],
    hw: &Hardware,
    method: Method,
    train: bool,
) -> NetworkEnergy {
    let bits = method_bitwidths(method);
    let fp_bits = method_bitwidths(Method::Fp32);
    let mut per_layer = Vec::new();
    let mut total = EnergyBreakdown::default();
    let mut opt_pj = 0.0;
    for layer in shapes {
        let b = if layer.always_fp { &fp_bits } else { &bits };
        let mut e = conv_energy(&layer.shape, hw, b, Phase::Forward);
        if train {
            e.add(conv_energy(&layer.shape, hw, b, Phase::Backward));
            // Optimizer update: read+write the stored weights and state.
            let params = layer.shape.filter_elems();
            let state_bits = if layer.always_fp || b.weight_store == 32 {
                // Adam: latent w (32) + m, v moments (2×32)
                32.0 + 64.0
            } else {
                // Boolean optimizer: 1-bit weight + INT16 accumulator
                1.0 + 16.0
            };
            let bytes = params * state_bits / 8.0;
            opt_pj += 2.0 * bytes * hw.dram().pj_per_byte; // read + write
        }
        // "B⊕LD with BN": FP BatchNorm on every non-FP conv output.
        if method == Method::BoldBn && !layer.always_fp && layer.shape.k > 1 {
            let elems = layer.shape.ofmap_elems();
            let bn = EnergyBreakdown {
                compute_pj: 2.0 * elems * hw.pj_per_mac_fp32,
                mem_pj: 2.0 * elems * 4.0 * hw.levels[1].pj_per_byte,
            };
            total.add(bn);
        }
        per_layer.push((layer.name.clone(), e.total()));
        total.add(e);
    }
    NetworkEnergy {
        method,
        hw_name: hw.name,
        per_layer_pj: per_layer,
        compute_pj: total.compute_pj,
        mem_pj: total.mem_pj,
        optimizer_pj: opt_pj,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::energy::hardware::{ascend, v100};

    #[test]
    fn vgg_shapes_match_paper() {
        let v = vgg_small_shapes(100);
        assert_eq!(v.len(), 8);
        assert_eq!(v[1].shape.c, 128);
        assert_eq!(v[5].shape.m, 512);
        assert_eq!(v[6].shape.c, 512 * 16);
        assert!(v[0].always_fp && v[7].always_fp);
    }

    #[test]
    fn resnet_shapes_scale_with_base() {
        let a = resnet18_shapes(1, 64);
        let b = resnet18_shapes(1, 256);
        let total_params =
            |v: &[NamedShape]| v.iter().map(|s| s.shape.filter_elems()).sum::<f64>();
        assert!(total_params(&b) > 10.0 * total_params(&a));
    }

    #[test]
    fn table2_ordering_holds() {
        // Training-energy ordering of Table 2 / Fig. 1:
        // FP > BinaryConnect > BinaryNet > B⊕LD+BN > B⊕LD.
        for hw in [ascend(), v100()] {
            let shapes = vgg_small_shapes(100);
            let e = |m| network_energy(&shapes, &hw, m, true).total_pj();
            let fp = e(Method::Fp32);
            let bc = e(Method::BinaryConnect);
            let bn = e(Method::BinaryNet);
            let bold = e(Method::Bold);
            let bold_bn = e(Method::BoldBn);
            assert!(bc < fp, "{}: BinaryConnect {bc} < FP {fp}", hw.name);
            assert!(bn < bc, "{}: BinaryNet {bn} < BinaryConnect {bc}", hw.name);
            assert!(bold < bn, "{}: B⊕LD {bold} < BinaryNet {bn}", hw.name);
            assert!(bold < bold_bn, "{}: BN costs extra", hw.name);
            assert!(bold_bn < bn, "{}: even with BN, B⊕LD beats BinaryNet", hw.name);
            // and the headline claim: an order of magnitude vs FP
            assert!(bold < fp / 8.0, "{}: bold {bold} vs fp {fp}", hw.name);
        }
    }

    #[test]
    fn inference_cheaper_than_training() {
        let hw = v100();
        let shapes = vgg_small_shapes(100);
        for m in Method::all() {
            let inf = network_energy(&shapes, &hw, m, false).total_pj();
            let tr = network_energy(&shapes, &hw, m, true).total_pj();
            assert!(tr > 2.0 * inf, "{m:?}");
        }
    }
}
