//! Per-method bitwidth accounting (Table 1 + Table 6's W/A/G column).
//!
//! What each method moves and computes with during *training* is the crux
//! of the paper's argument: latent-weight BNNs binarize the forward but
//! keep FP latent weights, FP gradients and FP optimizer state, while
//! B⊕LD keeps 1-bit weights/activations end-to-end with an INT16
//! backward signal (Table 6: W/A/G = 1/1/16).

/// Bitwidths of the three data streams per phase, in bits.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Bitwidths {
    /// Weights as used in the forward compute.
    pub weight_fwd: u32,
    /// Activations / feature maps.
    pub act: u32,
    /// Backward signal (gradients or Boolean-variation votes).
    pub grad: u32,
    /// Weight representation carried by the *optimizer* (latent weights).
    pub weight_store: u32,
    /// True when forward arithmetic is Boolean logic (XNOR+popcount)
    /// rather than MACs.
    pub logic_forward: bool,
}

/// The methods compared across Fig. 1 / Tables 2 & 5.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Method {
    Fp32,
    BinaryConnect,
    BinaryNet,
    XnorNet,
    /// B⊕LD without BN.
    Bold,
    /// B⊕LD with BN (extra FP BN tensors; same Boolean core).
    BoldBn,
}

impl Method {
    pub fn name(&self) -> &'static str {
        match self {
            Method::Fp32 => "Full-precision",
            Method::BinaryConnect => "BinaryConnect",
            Method::BinaryNet => "BinaryNet",
            Method::XnorNet => "XNOR-Net",
            Method::Bold => "B⊕LD w/o BN",
            Method::BoldBn => "B⊕LD with BN",
        }
    }

    pub fn all() -> [Method; 6] {
        [
            Method::Fp32,
            Method::BinaryConnect,
            Method::BinaryNet,
            Method::XnorNet,
            Method::Bold,
            Method::BoldBn,
        ]
    }
}

/// Table 1 + §4 bitwidths for each method.
pub fn method_bitwidths(m: Method) -> Bitwidths {
    match m {
        Method::Fp32 => Bitwidths {
            weight_fwd: 32,
            act: 32,
            grad: 32,
            weight_store: 32,
            logic_forward: false,
        },
        // BinaryConnect: 1-bit weights in the forward, 32-bit activations,
        // FP latent weights + FP gradients in training.
        Method::BinaryConnect => Bitwidths {
            weight_fwd: 1,
            act: 32,
            grad: 32,
            weight_store: 32,
            logic_forward: false,
        },
        // BinaryNet / XNOR-Net: 1/1 forward (XNOR+popcount inference
        // arithmetic) but still FP latent weights + FP gradients.
        Method::BinaryNet | Method::XnorNet => Bitwidths {
            weight_fwd: 1,
            act: 1,
            grad: 32,
            weight_store: 32,
            logic_forward: true,
        },
        // B⊕LD: native Boolean weights (stored as 1 bit), Boolean
        // activations, INT16 backward signal (Table 6: 1/1/16).
        Method::Bold | Method::BoldBn => Bitwidths {
            weight_fwd: 1,
            act: 1,
            grad: 16,
            weight_store: 1,
            logic_forward: true,
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bold_is_native_boolean() {
        let b = method_bitwidths(Method::Bold);
        assert_eq!(b.weight_store, 1, "no latent FP weights");
        assert_eq!((b.weight_fwd, b.act, b.grad), (1, 1, 16), "Table 6 W/A/G");
        assert!(b.logic_forward);
    }

    #[test]
    fn bnns_keep_fp_latent_weights() {
        for m in [Method::BinaryConnect, Method::BinaryNet, Method::XnorNet] {
            let b = method_bitwidths(m);
            assert_eq!(b.weight_store, 32, "{m:?} trains on FP latent weights");
            assert_eq!(b.grad, 32);
        }
    }

    #[test]
    fn binaryconnect_keeps_fp_activations() {
        assert_eq!(method_bitwidths(Method::BinaryConnect).act, 32);
        assert_eq!(method_bitwidths(Method::BinaryNet).act, 1);
    }
}
