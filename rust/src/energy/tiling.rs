//! Loop-tiling search (Algorithm 9, Table 17): choose per-level tile
//! sizes (M_i filters, N_i batch, H_i×W_i ifmap plane) under the buffer
//! capacity constraint, minimizing the data-movement energy of the
//! weight-stationary / input-cycling dataflow (Algorithm 10).
//!
//! The paper notes the exact problem is NP-hard; like the paper we search
//! a structured candidate set — halving ladders per dimension — which
//! preserves the qualitative behaviour (large buffers → big tiles → few
//! re-fetches) at tractable cost.

use super::hardware::Hardware;
use super::layer_cost::ConvShape;

/// Tile parameters at one memory level (Table 17 row).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LevelTile {
    pub m: usize,
    pub n: usize,
    pub h: usize,
    pub w: usize,
}

/// Chosen tiles for every level below DRAM (levels\[1..\]).
#[derive(Debug, Clone)]
pub struct Tiling {
    pub tiles: Vec<LevelTile>,
}

impl Tiling {
    /// Tile at hierarchy level `lvl` (0 = DRAM = full tensor).
    pub fn at(&self, shape: &ConvShape, lvl: usize) -> LevelTile {
        if lvl == 0 {
            LevelTile { m: shape.m, n: shape.n, h: shape.h, w: shape.w }
        } else {
            self.tiles[lvl - 1]
        }
    }
}

/// Halving ladder {v, ⌈v/2⌉, …, 1}, deduped.
fn ladder(v: usize) -> Vec<usize> {
    let mut out = Vec::new();
    let mut x = v.max(1);
    loop {
        out.push(x);
        if x == 1 {
            break;
        }
        x = x.div_ceil(2);
    }
    out.dedup();
    out
}

/// Bytes needed at a level for a tile (Eq. 50): IFMAPS + FILTERS.
fn tile_bytes(shape: &ConvShape, t: &LevelTile, bits_i: u32, bits_f: u32) -> f64 {
    let q_i = (t.n * shape.c * t.h * t.w) as f64 * bits_i as f64 / 8.0;
    let q_f = (t.m * shape.c * shape.k * shape.k) as f64 * bits_f as f64 / 8.0;
    q_i + q_f
}

/// Output-tile height for an input-tile height (same stride/kernel).
pub fn out_dim(in_dim: usize, k: usize, stride: usize) -> usize {
    if in_dim < k {
        1
    } else {
        (in_dim - k) / stride + 1
    }
}

/// Per-level movement-cost proxy used by the greedy search (the ε_i term
/// of Algorithm 9 line 9): accesses from the parent level for this tile
/// choice, costed at the parent's per-byte energy.
fn level_cost(
    shape: &ConvShape,
    parent: &LevelTile,
    tile: &LevelTile,
    parent_pj: f64,
    bits_i: u32,
    bits_f: u32,
) -> f64 {
    // IFMAPS re-fetched once per filter block of the parent (Alg. 10):
    let refetch_i = (parent.m as f64 / tile.m as f64).ceil();
    // halo overlap: tiles of H_i cover H with overlap k−1
    let oh_t = out_dim(tile.h, shape.k, shape.stride).max(1);
    let ow_t = out_dim(tile.w, shape.k, shape.stride).max(1);
    let halo = (tile.h as f64 / oh_t as f64) * (tile.w as f64 / ow_t as f64);
    let bytes_i = (parent.n * shape.c * parent.h * parent.w) as f64 * bits_i as f64 / 8.0;
    // FILTERS re-fetched once per (batch × spatial) block of the parent:
    let oh_p = out_dim(parent.h, shape.k, shape.stride).max(1);
    let ow_p = out_dim(parent.w, shape.k, shape.stride).max(1);
    let refetch_f = (parent.n as f64 / tile.n as f64).ceil()
        * (oh_p as f64 / oh_t as f64).ceil()
        * (ow_p as f64 / ow_t as f64).ceil();
    let bytes_f = (parent.m * shape.c * shape.k * shape.k) as f64 * bits_f as f64 / 8.0;
    (bytes_i * refetch_i * halo + bytes_f * refetch_f) * parent_pj
}

/// Algorithm 9: greedy per-level search over halving ladders.
pub fn search_tiling(shape: &ConvShape, hw: &Hardware, bits_i: u32, bits_f: u32) -> Tiling {
    let mut tiles = Vec::new();
    let mut parent = LevelTile { m: shape.m, n: shape.n, h: shape.h, w: shape.w };
    for lvl in 1..hw.n_levels() {
        let cap = hw.levels[lvl].capacity as f64;
        let parent_pj = hw.levels[lvl - 1].pj_per_byte;
        let mut best: Option<(f64, LevelTile)> = None;
        for &m in &ladder(parent.m) {
            for &n in &ladder(parent.n) {
                for &h in &ladder(parent.h) {
                    for &w in &ladder(parent.w) {
                        let t = LevelTile { m, n, h: h.max(shape.k.min(parent.h)), w: w.max(shape.k.min(parent.w)) };
                        if tile_bytes(shape, &t, bits_i, bits_f) > cap {
                            continue;
                        }
                        let cost = level_cost(shape, &parent, &t, parent_pj, bits_i, bits_f);
                        if best.map_or(true, |(bc, _)| cost < bc) {
                            best = Some((cost, t));
                        }
                    }
                }
            }
        }
        // Fall back to the minimal tile if nothing fits (tiny buffers).
        let chosen = best.map(|(_, t)| t).unwrap_or(LevelTile {
            m: 1,
            n: 1,
            h: shape.k.min(parent.h),
            w: shape.k.min(parent.w),
        });
        tiles.push(chosen);
        parent = chosen;
    }
    Tiling { tiles }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::energy::hardware::{ascend, v100};

    fn shape() -> ConvShape {
        ConvShape { n: 32, c: 64, m: 128, h: 32, w: 32, k: 3, stride: 1, pad: 1 }
    }

    #[test]
    fn tiles_respect_capacity() {
        for hw in [ascend(), v100()] {
            for bits in [(32, 32), (1, 1), (16, 1)] {
                let t = search_tiling(&shape(), &hw, bits.0, bits.1);
                for (lvl, tile) in t.tiles.iter().enumerate() {
                    let cap = hw.levels[lvl + 1].capacity as f64;
                    assert!(
                        tile_bytes(&shape(), tile, bits.0, bits.1) <= cap,
                        "{} level {} tile {:?} overflows",
                        hw.name,
                        lvl + 1,
                        tile
                    );
                }
            }
        }
    }

    #[test]
    fn tiles_shrink_monotonically() {
        let hw = ascend();
        let t = search_tiling(&shape(), &hw, 32, 32);
        let mut prev = LevelTile { m: 128, n: 32, h: 32, w: 32 };
        for tile in &t.tiles {
            assert!(tile.m <= prev.m && tile.n <= prev.n && tile.h <= prev.h);
            prev = *tile;
        }
    }

    #[test]
    fn binary_data_allows_bigger_tiles() {
        // 1-bit streams fit 32× more data per buffer → innermost tile
        // should hold at least as many elements as the 32-bit one.
        let hw = v100();
        let t32 = search_tiling(&shape(), &hw, 32, 32);
        let t1 = search_tiling(&shape(), &hw, 1, 1);
        let elems = |t: &LevelTile| t.m * t.n * t.h * t.w;
        let last32 = t32.tiles.last().unwrap();
        let last1 = t1.tiles.last().unwrap();
        assert!(elems(last1) >= elems(last32), "{last1:?} vs {last32:?}");
    }

    #[test]
    fn ladder_contains_extremes() {
        let l = ladder(37);
        assert_eq!(*l.first().unwrap(), 37);
        assert_eq!(*l.last().unwrap(), 1);
    }
}
