//! Dense-prediction reports: Table 3 (super-resolution), Table 4
//! (segmentation mIoU), Table 12 (class-wise IoU / BOOL-ASPP ablation),
//! Table 13 (segmentation heads).

use crate::data::{SegDataset, SrDataset};
use crate::models::edsr::psnr;
use crate::models::segnet::{class_iou, mean_iou};
use crate::models::{edsr_small, segnet_boolean, EdsrConfig, SegNetConfig};
use crate::nn::{l1_loss, softmax_cross_entropy_nchw, Layer, ParamStore, Value};
use crate::optim::{Adam, BooleanOptimizer};
use crate::util::Rng;

/// Train an EDSR model with the paper's recipe (L1 loss, Adam for FP,
/// Boolean optimizer for Boolean params); return mean PSNR on a val set.
fn train_sr(cfg: &EdsrConfig, steps: usize, seed: u64) -> f32 {
    let train = SrDataset::textures(96, cfg.colors, 8, cfg.scale, seed);
    let val = SrDataset::textures(16, cfg.colors, 8, cfg.scale, seed + 1);
    let mut rng = Rng::new(seed);
    let mut model = edsr_small(cfg, &mut rng);
    let bool_opt = BooleanOptimizer::new(6.0);
    let mut adam = Adam::new(1e-3);
    let mut store = ParamStore::new();
    let mut sampler = crate::data::BatchSampler::new(train.n, 8, seed);
    for _ in 0..steps {
        let idx = sampler.next_batch();
        let (lr, hr) = train.batch(&idx);
        let pred = model.forward(Value::F32(lr), true).expect_f32("sr");
        let out = l1_loss(&pred, &hr);
        store.zero_grads();
        let _ = model.backward(out.grad, &mut store);
        let mut params = model.params();
        bool_opt.step(&mut params, &mut store);
        adam.step(&mut params, &mut store);
    }
    // validation PSNR
    let idx: Vec<usize> = (0..val.n).collect();
    let (lr, hr) = val.batch(&idx);
    let pred = model.forward(Value::F32(lr), false).expect_f32("sr");
    psnr(&pred, &hr)
}

/// Table 3: PSNR at ×2/×3/×4, FP small-EDSR vs Boolean EDSR.
pub fn table3(quick: bool) -> Result<(), String> {
    println!("Table 3 — super-resolution PSNR (dB) on synthetic textures (stand-in for Set5/...)");
    println!("{:<8} {:<22} {:>10}", "scale", "method", "PSNR (dB)");
    let steps = if quick { 60 } else { 400 };
    let scales: &[usize] = if quick { &[2] } else { &[2, 3, 4] };
    for &scale in scales {
        for boolean in [false, true] {
            let cfg = EdsrConfig { features: 16, blocks: 3, scale, boolean, ..Default::default() };
            let p = train_sr(&cfg, steps, 31 + scale as u64);
            println!(
                "x{:<7} {:<22} {:>10.2}",
                scale,
                if boolean { "B⊕LD EDSR" } else { "SMALL EDSR (FP)" },
                p
            );
        }
    }
    println!("(paper ×2: FP 38.01 vs B⊕LD 37.42 on Set5 — ~0.5–1.5 dB gap, shrinking at ×2)");
    Ok(())
}

/// Train a segmentation net; returns (mIoU, per-class IoU).
fn train_seg(
    scfg: &SegNetConfig,
    data: &SegDataset,
    val: &SegDataset,
    steps: usize,
    rcs: bool,
    seed: u64,
) -> (f32, Vec<f32>) {
    let mut rng = Rng::new(seed);
    let mut model = segnet_boolean(scfg, &mut rng);
    let bool_opt = BooleanOptimizer::new(6.0);
    let mut adam = Adam::new(1e-3);
    let mut store = ParamStore::new();
    let mut sampler = crate::data::BatchSampler::new(data.n, 8, seed);
    if rcs {
        sampler = crate::data::BatchSampler::new(data.n, 8, seed).with_rcs(
            &data.dominant_class(),
            scfg.classes,
            0.5,
        );
    }
    for _ in 0..steps {
        let idx = sampler.next_batch();
        let (x, labels) = data.batch(&idx);
        let logits = model.forward(Value::F32(x), true).expect_f32("seg");
        let out = softmax_cross_entropy_nchw(&logits, &labels, None);
        store.zero_grads();
        let _ = model.backward(out.grad, &mut store);
        let mut params = model.params();
        bool_opt.step(&mut params, &mut store);
        adam.step(&mut params, &mut store);
    }
    // evaluate
    let idx: Vec<usize> = (0..val.n).collect();
    let (x, labels) = val.batch(&idx);
    let logits = model.forward(Value::F32(x), false).expect_f32("seg");
    let rows = logits.nchw_to_rows();
    let preds = rows.argmax_rows();
    (
        mean_iou(&preds, &labels, scfg.classes, None),
        class_iou(&preds, &labels, scfg.classes),
    )
}

fn seg_data(quick: bool, seed: u64) -> (SegDataset, SegDataset) {
    let hw = 16;
    let n = if quick { 48 } else { 160 };
    (
        SegDataset::scenes(n, 6, 3, hw, 0.55, seed),
        SegDataset::scenes(24, 6, 3, hw, 0.55, seed + 100),
    )
}

/// Table 4: segmentation mIoU — Boolean model vs an FP-width reference.
pub fn table4(quick: bool) -> Result<(), String> {
    println!("Table 4 — segmentation mIoU on synthetic scenes (stand-in for Cityscapes/VOC)");
    let steps = if quick { 50 } else { 300 };
    let (train, val) = seg_data(quick, 5);
    // FP reference: same topology with much wider FP-equivalent capacity
    // is out of scope for the scaled run; we report B⊕LD with the paper's
    // BOOL-ASPP and the naive variant for the gap.
    let (miou, _) = train_seg(
        &SegNetConfig { hw: 16, width: 12, naive_aspp: false, ..Default::default() },
        &train,
        &val,
        steps,
        true,
        3,
    );
    let (miou_naive, _) = train_seg(
        &SegNetConfig { hw: 16, width: 12, naive_aspp: true, ..Default::default() },
        &train,
        &val,
        steps,
        false,
        3,
    );
    println!("{:<36} {:>10.1}", "B⊕LD (BOOL-ASPP + RCS)  mIoU(%)", miou * 100.0);
    println!("{:<36} {:>10.1}", "B⊕LD (naive ASPP)       mIoU(%)", miou_naive * 100.0);
    println!("(paper: 67.4 vs naive 66.3 on Cityscapes; FP baseline 70.7)");
    Ok(())
}

/// Table 12: class-wise IoU, naive BOOL-ASPP vs BOOL-ASPP + RCS.
pub fn table12(quick: bool) -> Result<(), String> {
    println!("Table 12 — class-wise IoU: naive ASPP vs BOOL-ASPP (+RCS), rare classes improve");
    let steps = if quick { 50 } else { 300 };
    let (train, val) = seg_data(quick, 9);
    let freqs = train.class_frequencies();
    let (m_naive, iou_naive) = train_seg(
        &SegNetConfig { hw: 16, width: 12, naive_aspp: true, ..Default::default() },
        &train,
        &val,
        steps,
        false,
        4,
    );
    let (m_bold, iou_bold) = train_seg(
        &SegNetConfig { hw: 16, width: 12, naive_aspp: false, ..Default::default() },
        &train,
        &val,
        steps,
        true,
        4,
    );
    println!(
        "{:<8} {:>10} {:>14} {:>18} {:>8}",
        "class", "freq(%)", "naive IoU(%)", "BOOL-ASPP+RCS(%)", "Δ"
    );
    for c in 0..6 {
        println!(
            "{:<8} {:>10.2} {:>14.1} {:>18.1} {:>8.1}",
            c,
            freqs[c] * 100.0,
            iou_naive[c] * 100.0,
            iou_bold[c] * 100.0,
            (iou_bold[c] - iou_naive[c]) * 100.0
        );
    }
    println!(
        "mIoU: naive {:.1}% → BOOL-ASPP+RCS {:.1}% (paper: 66.3 → 67.4)",
        m_naive * 100.0,
        m_bold * 100.0
    );
    Ok(())
}

/// Table 13: segmentation heads — FCN-32s-like (no context module) vs
/// DeepLab-like (BOOL-ASPP).
pub fn table13(quick: bool) -> Result<(), String> {
    println!("Table 13 — segmentation heads (FCN-like vs DeepLab/BOOL-ASPP-like)");
    let steps = if quick { 50 } else { 300 };
    let (train, val) = seg_data(quick, 13);
    // FCN-like: reuse the segnet with the naive context module as the
    // weaker head (no integer GAP, no RCS).
    let (m_fcn, _) = train_seg(
        &SegNetConfig { hw: 16, width: 12, naive_aspp: true, ..Default::default() },
        &train,
        &val,
        steps,
        false,
        6,
    );
    let (m_dl, _) = train_seg(
        &SegNetConfig { hw: 16, width: 12, naive_aspp: false, ..Default::default() },
        &train,
        &val,
        steps,
        true,
        6,
    );
    println!("{:<30} {:>10.1}", "B⊕LD + FCN-like head  mIoU(%)", m_fcn * 100.0);
    println!("{:<30} {:>10.1}", "B⊕LD + ASPP head      mIoU(%)", m_dl * 100.0);
    println!("(paper VOC: FCN-32s head 60.1 vs DeepLabV3 head 67.3)");
    Ok(())
}
