//! Classification reports: Fig. 1, Tables 2/5/6/9/10.

use crate::baselines::{bnn_vgg_small, BnnKind};
use crate::config::TrainConfig;
use crate::coordinator::{evaluate_classifier, ClassifierTrainer};
use crate::data::ImageDataset;
use crate::energy::{network_energy, resnet18_shapes, vgg_small_shapes, Method};
use crate::models::{resnet_boolean, vgg_small, ResNetConfig, VggConfig, VggKind};
use crate::nn::Sequential;
use crate::util::Rng;

fn cifar_cfg(quick: bool) -> TrainConfig {
    TrainConfig {
        steps: if quick { 60 } else { 400 },
        batch: 64,
        lr_bool: 8.0,
        lr_fp: 2e-3,
        train_size: if quick { 512 } else { 2048 },
        val_size: if quick { 128 } else { 512 },
        hw: 16,
        width_mult: 0.125,
        ..Default::default()
    }
}

fn cifar_data(cfg: &TrainConfig, classes: usize, seed: u64) -> (ImageDataset, ImageDataset) {
    ImageDataset::cifar_like(cfg.train_size + cfg.val_size, classes, 3, cfg.hw, 0.25, seed)
        .split(cfg.train_size)
}

/// Build a VGG-SMALL variant for a method id.
fn build_vgg(method: Method, cfg: &TrainConfig, rng: &mut Rng) -> Sequential {
    let vcfg = VggConfig {
        hw: cfg.hw,
        width_mult: cfg.width_mult,
        classes: cfg.classes,
        with_bn: matches!(method, Method::BoldBn),
        kind: if matches!(method, Method::Fp32) { VggKind::Fp } else { VggKind::Bold },
        ..Default::default()
    };
    match method {
        Method::Fp32 | Method::Bold | Method::BoldBn => vgg_small(&vcfg, rng),
        Method::BinaryConnect => bnn_vgg_small(BnnKind::BinaryConnect, &vcfg, rng),
        Method::BinaryNet => bnn_vgg_small(BnnKind::BinaryNet, &vcfg, rng),
        Method::XnorNet => bnn_vgg_small(BnnKind::XnorNet, &vcfg, rng),
    }
}

/// Train one method and return (val accuracy %, loss curve tail).
fn train_method(method: Method, cfg: &TrainConfig, quick: bool) -> f32 {
    let mut cfg = cfg.clone();
    if matches!(method, Method::Fp32 | Method::BinaryConnect | Method::BinaryNet | Method::XnorNet)
    {
        cfg.lr_bool = 0.0; // no Boolean params in those nets
    }
    if matches!(method, Method::BoldBn) {
        // BN normalizes the backward signal, so the Boolean accumulator
        // needs a much larger η (the paper: 150 with BN vs 12 without).
        cfg.lr_bool *= 8.0;
    }
    let _ = quick;
    let (train, val) = cifar_data(&cfg, cfg.classes, cfg.seed * 7 + 1);
    let mut rng = Rng::new(cfg.seed);
    let mut model = build_vgg(method, &cfg, &mut rng);
    let mut trainer = ClassifierTrainer::new(&cfg);
    let report = trainer.fit(&mut model, &train, &val, &cfg, false);
    report.val_acc * 100.0
}

/// Energy (% of FP) on the paper-exact VGG-SMALL shapes.
fn vgg_energy_pct(method: Method, v100: bool) -> f64 {
    let hw = if v100 { crate::energy::V100() } else { crate::energy::ASCEND() };
    let shapes = vgg_small_shapes(100); // paper batch 100-ish per GPU
    let fp = network_energy(&shapes, &hw, Method::Fp32, true).total_pj();
    network_energy(&shapes, &hw, method, true).total_pj() / fp * 100.0
}

/// Fig. 1: accuracy vs training-energy scatter, VGG-SMALL / CIFAR10 / V100.
pub fn fig1(quick: bool) -> Result<(), String> {
    println!("Fig. 1 — accuracy vs training energy (VGG-SMALL, CIFAR10-like, V100 model)");
    println!("{:<18} {:>10} {:>22}", "method", "acc (%)", "energy vs FP (%)");
    let cfg = cifar_cfg(quick);
    for m in Method::all() {
        let acc = train_method(m, &cfg, quick);
        let e = vgg_energy_pct(m, true);
        println!("{:<18} {:>10.2} {:>22.2}", m.name(), acc, e);
    }
    println!("(paper: B⊕LD 36× less energy than FP, more accurate than the BNNs)");
    Ok(())
}

/// Table 2: VGG-SMALL on CIFAR10 — accuracy + Cons.% on both hardwares.
pub fn table2(quick: bool) -> Result<(), String> {
    println!("Table 2 — VGG-SMALL / CIFAR10-like: W/A, Acc, Cons.% (Ascend, V100)");
    println!(
        "{:<18} {:>6} {:>9} {:>14} {:>14}",
        "method", "W/A", "Acc(%)", "Cons.% Ascend", "Cons.% V100"
    );
    let cfg = cifar_cfg(quick);
    let rows: &[(Method, &str)] = &[
        (Method::Fp32, "32/32"),
        (Method::BinaryConnect, "1/32"),
        (Method::XnorNet, "1/1"),
        (Method::BinaryNet, "1/1"),
        (Method::Bold, "1/1"),
        (Method::BoldBn, "1/1"),
    ];
    for &(m, wa) in rows {
        let acc = train_method(m, &cfg, quick);
        println!(
            "{:<18} {:>6} {:>9.2} {:>14.2} {:>14.2}",
            m.name(),
            wa,
            acc,
            vgg_energy_pct(m, false),
            vgg_energy_pct(m, true)
        );
    }
    println!("(paper: FP 93.80 / B⊕LD 90.29 / B⊕LD+BN 92.37; Cons. 100 / 2.78–3.64 / 3.71–4.87)");
    Ok(())
}

/// Table 5: ResNet18-family — Boolean ResNet at several base widths +
/// energy on the paper-exact ImageNet shapes.
pub fn table5(quick: bool) -> Result<(), String> {
    println!("Table 5 — Boolean ResNet (Block I family): base-width sweep + ImageNet-shape energy");
    let mut cfg = cifar_cfg(quick);
    cfg.steps = if quick { 40 } else { 250 };
    cfg.lr_bool = 4.0;
    let (train, val) = cifar_data(&cfg, cfg.classes, 99);
    println!(
        "{:<26} {:>9} {:>14} {:>14}",
        "model", "Acc(%)", "Cons.% Ascend", "Cons.% V100"
    );
    // FP energy reference on paper shapes (base 64)
    let e_pct = |base: usize, m: Method, v100: bool| -> f64 {
        let hw = if v100 { crate::energy::V100() } else { crate::energy::ASCEND() };
        let fp = network_energy(&resnet18_shapes(32, 64), &hw, Method::Fp32, true).total_pj();
        network_energy(&resnet18_shapes(32, base), &hw, m, true).total_pj() / fp * 100.0
    };
    println!(
        "{:<26} {:>9} {:>14.2} {:>14.2}",
        "FP ResNet18 (base 64)", "—", 100.0, 100.0
    );
    for (base, paper_base) in [(8usize, 64usize), (16, 128), (32, 256)] {
        let mut rng = Rng::new(cfg.seed + base as u64);
        let rcfg = ResNetConfig {
            base,
            blocks: vec![2, 2],
            hw: cfg.hw,
            classes: cfg.classes,
            ..Default::default()
        };
        let mut model = resnet_boolean(&rcfg, &mut rng);
        let mut trainer = ClassifierTrainer::new(&cfg);
        let report = trainer.fit(&mut model, &train, &val, &cfg, false);
        println!(
            "{:<26} {:>9.2} {:>14.2} {:>14.2}",
            format!("B⊕LD (base {paper_base})"),
            report.val_acc * 100.0,
            e_pct(paper_base, Method::Bold, false),
            e_pct(paper_base, Method::Bold, true)
        );
    }
    println!("(paper: base 64→51.8%, base 256→70.0% beating FP 69.7% at 24.45% energy)");
    Ok(())
}

/// Table 6: adaptability — train-from-scratch vs fine-tuning transfers
/// across two related synthetic datasets (refs A–H of the paper).
pub fn table6(quick: bool) -> Result<(), String> {
    println!("Table 6 — fine-tuning adaptability (refs C/D/F/H) + FP baselines (A/B/E/G)");
    let mut cfg = cifar_cfg(quick);
    cfg.steps = if quick { 50 } else { 300 };
    // two tasks with the same input space: 10-class and 4-class variants
    let (tr10, va10) = cifar_data(&cfg, 10, 11);
    let (tr4, va4) = ImageDataset::cifar_like(cfg.train_size + cfg.val_size, 4, 3, cfg.hw, 0.25, 22)
        .split(cfg.train_size);

    let build = |kind: VggKind, classes: usize, rng: &mut Rng, cfg: &TrainConfig| {
        vgg_small(
            &VggConfig {
                kind,
                hw: cfg.hw,
                width_mult: cfg.width_mult,
                classes,
                ..Default::default()
            },
            rng,
        )
    };
    #[allow(clippy::too_many_arguments)]
    let run = |name: &str,
                   kind: VggKind,
                   pre: Option<(&ImageDataset, &ImageDataset, usize)>,
                   tr: &ImageDataset,
                   va: &ImageDataset,
                   classes: usize| {
        let mut rng = Rng::new(7);
        let mut cfg_l = cfg.clone();
        cfg_l.classes = classes;
        if kind == VggKind::Fp {
            cfg_l.lr_bool = 0.0;
        }
        let mut model = build(kind, classes, &mut rng, &cfg_l);
        let mut trainer = ClassifierTrainer::new(&cfg_l);
        if let Some((ptr, pva, pcls)) = pre {
            // pre-train on the source task with a temporary head size:
            // heads differ per task, so pre-train a same-head model and
            // transfer everything (heads here share `classes`): emulate by
            // pre-training on the source dataset remapped mod `classes`.
            let src = ptr.clone_remap(classes);
            let src_val = pva.clone_remap(classes);
            let _ = pcls;
            let mut pre_cfg = cfg_l.clone();
            pre_cfg.steps /= 2;
            let _ = trainer.fit(&mut model, &src, &src_val, &pre_cfg, false);
        }
        let report = trainer.fit(&mut model, tr, va, &cfg_l, false);
        println!("{:<44} acc {:>6.2}%", name, report.val_acc * 100.0);
        report.val_acc
    };

    let a = run("A: FP, random init, task-10", VggKind::Fp, None, &tr10, &va10, 10);
    let c = run("C: B⊕LD, random init, task-10", VggKind::Bold, None, &tr10, &va10, 10);
    let d = run("D: B⊕LD, random init, task-4", VggKind::Bold, None, &tr4, &va4, 4);
    let f = run(
        "F: B⊕LD, init from task-10 run, FT on task-4",
        VggKind::Bold,
        Some((&tr10, &va10, 10)),
        &tr4,
        &va4,
        4,
    );
    let h = run(
        "H: B⊕LD, init from task-4 run, FT on task-10",
        VggKind::Bold,
        Some((&tr4, &va4, 4)),
        &tr10,
        &va10,
        10,
    );
    let _ = (a, c);
    println!(
        "(paper: FT ≈ from-scratch — here F {:.2} vs D {:.2}, H {:.2} vs C {:.2})",
        f * 100.0,
        d * 100.0,
        h * 100.0,
        c * 100.0
    );
    Ok(())
}

/// Table 9: modified VGG-SMALL (single FC) comparison.
pub fn table9(quick: bool) -> Result<(), String> {
    println!("Table 9 — modified VGG-SMALL (1 FC): Boolean vs FP vs BNNs");
    let cfg = cifar_cfg(quick);
    println!("{:<18} {:>12} {:>12} {:>9}", "method", "fwd W/A", "train W/G", "Acc(%)");
    let rows: &[(Method, &str, &str)] = &[
        (Method::Fp32, "32/32", "32/32"),
        (Method::XnorNet, "1/1", "32/32"),
        (Method::BinaryNet, "1/1", "32/32"),
        (Method::Bold, "1/1", "1/16"),
    ];
    for &(m, wa, wg) in rows {
        let acc = train_method(m, &cfg, quick);
        println!("{:<18} {:>12} {:>12} {:>9.2}", m.name(), wa, wg, acc);
    }
    println!("(paper: FP 93.8, XNOR 87.4, B⊕LD 90.8 with 1/16 training bitwidth)");
    Ok(())
}

/// Table 10: block-design ablation — shortcut kernel size, base width,
/// augmentation.
pub fn table10(quick: bool) -> Result<(), String> {
    println!("Table 10 — Boolean ResNet block ablation (shortcut k, base width, augmentation)");
    let mut cfg = cifar_cfg(quick);
    cfg.steps = if quick { 40 } else { 250 };
    cfg.lr_bool = 4.0;
    let (train, val) = cifar_data(&cfg, cfg.classes, 55);
    println!(
        "{:<12} {:>10} {:>12} {:>9}",
        "base", "shortcut", "augment", "Acc(%)"
    );
    for (base, k, augment) in
        [(8usize, 1usize, false), (8, 3, false), (16, 3, false), (16, 3, true)]
    {
        let mut rng = Rng::new(cfg.seed + (base * 10 + k) as u64);
        let rcfg = ResNetConfig {
            base,
            blocks: vec![2, 2],
            hw: cfg.hw,
            classes: cfg.classes,
            shortcut_k: k,
            ..Default::default()
        };
        let mut model = resnet_boolean(&rcfg, &mut rng);
        let mut trainer = ClassifierTrainer::new(&cfg);
        // augmentation: crop+flip on each batch
        let mut sampler = crate::data::BatchSampler::new(train.n, cfg.batch, cfg.seed);
        let mut arng = Rng::new(77);
        for step in 0..cfg.steps {
            let idx = sampler.next_batch();
            let (mut x, labels) = train.batch(&idx);
            if augment {
                x = crate::data::random_crop_flip(&x, 2, &mut arng);
            }
            let _ = trainer.train_step(&mut model, crate::nn::Value::F32(x), &labels, step);
        }
        let acc = evaluate_classifier(&mut model, &val, cfg.batch);
        println!(
            "{:<12} {:>10} {:>12} {:>9.2}",
            base,
            format!("{k}x{k}"),
            if augment { "crop+flip" } else { "basic" },
            acc * 100.0
        );
    }
    println!("(paper: 3×3 shortcut > 1×1; wider base > narrower; augmentation helps ~3 pts)");
    Ok(())
}

// Helper: remap labels mod `classes` for the Table 6 head transfer.
impl ImageDataset {
    fn clone_remap(&self, classes: usize) -> ImageDataset {
        ImageDataset {
            images: self.images.clone(),
            labels: self.labels.iter().map(|&l| l % classes).collect(),
            n: self.n,
            c: self.c,
            h: self.h,
            w: self.w,
            classes,
        }
    }
}
