//! Math/diagnostic reports: Table 8 (variation truth table), Fig. 4
//! (backprop signal mean/σ ratio), Fig. 5 (E[tanh'(u)²] vs m), hardware
//! tables (14/15) and the Theorem 3.16 convergence experiment.

use crate::logic::{variation, BoolFn, B3, F, T};
use crate::nn::{ParamRef, ParamStore};
use crate::optim::BooleanOptimizer;
use crate::tensor::{BitMatrix, Tensor};
use crate::util::Rng;

fn b3s(x: B3) -> &'static str {
    match x {
        T => "T",
        F => "F",
        B3::Zero => "0",
    }
}

/// Table 8: variation truth table of f(x) = xor(a, x) — exact.
pub fn table8() -> Result<(), String> {
    println!("Table 8 — variation truth table of f(x) = xor(a, x)");
    println!(
        "{:>3} {:>3} {:>4} {:>12} {:>8} {:>9} {:>13} {:>6}",
        "a", "x", "¬x", "δ(x→¬x)", "f(a,x)", "f(a,¬x)", "δf(x→¬x)", "f'(x)"
    );
    for &a in &[T, F] {
        for &x in &[T, F] {
            let f = BoolFn::new(T.xor(a), F.xor(a));
            let nx = x.not();
            let dx = x.delta_to(nx);
            let fx = f.eval(x);
            let fnx = f.eval(nx);
            let df = fx.delta_to(fnx);
            let fp = variation(&f, x);
            println!(
                "{:>3} {:>3} {:>4} {:>12} {:>8} {:>9} {:>13} {:>6}",
                b3s(a), b3s(x), b3s(nx), b3s(dx), b3s(fx), b3s(fnx), b3s(df), b3s(fp)
            );
            // paper's result: f'(x) = ¬a
            assert_eq!(fp, a.not());
        }
    }
    println!("⇒ f'(x) = ¬a for all x (Example 3.9) — matches the paper exactly.");
    Ok(())
}

/// Fig. 5: E[(tanh'(αu))²] for u the pre-activation of a fan-in-m Boolean
/// neuron, by exact enumeration (Eqs. 38–41). Shows the ≈1/2 plateau that
/// justifies the Var(Z^{l-1}) = (m/2)·Var(Z^l) rule (Eq. 42).
pub fn fig5() -> Result<(), String> {
    println!("Fig. 5 — E[tanh'(αu)²] vs layer size m (exact enumeration, Eq. 41)");
    println!("{:>8} {:>14}", "m", "E[tanh'^2]");
    for &m in &[8usize, 16, 32, 64, 128, 256, 512, 1024] {
        let alpha = crate::nn::BackwardScale::alpha(m) as f64;
        // ln C(m, j) via lgamma-free accumulation
        let mut logc = vec![0.0f64; m + 1];
        for j in 1..=m {
            logc[j] = logc[j - 1] + ((m - j + 1) as f64).ln() - (j as f64).ln();
        }
        let ln2m = (m as f64) * std::f64::consts::LN_2;
        let mut e = 0.0f64;
        for j in 0..=m {
            // u = 2j − m (parity: u has the same parity as m)
            let u = (2 * j) as f64 - m as f64;
            let p = (logc[j] - ln2m).exp();
            let t = (alpha * u).tanh();
            let w = 1.0 - t * t;
            e += p * w * w;
        }
        println!("{:>8} {:>14.4}", m, e);
    }
    println!("(paper: plateaus near 1/2 for practical m — hence Eq. 42's m/2 factor)");
    Ok(())
}

/// Fig. 4: ratio |mean|/σ of the backprop signal per layer while training
/// a small Boolean CNN — the assumption μ ≪ σ behind Appendix C.
pub fn fig4(quick: bool) -> Result<(), String> {
    use crate::config::TrainConfig;
    use crate::coordinator::ClassifierTrainer;
    use crate::data::ImageDataset;
    use crate::models::{vgg_small, VggConfig};
    use crate::nn::{Layer, Value};

    println!("Fig. 4 — |mean|/σ of the backprop signal (should be ≪ 1)");
    let cfg = TrainConfig {
        steps: if quick { 20 } else { 80 },
        batch: 32,
        hw: 16,
        width_mult: 0.125,
        lr_bool: 8.0,
        ..Default::default()
    };
    let (train, _val) =
        ImageDataset::cifar_like(512 + 64, 10, 3, cfg.hw, 0.25, 3).split(512);
    let mut rng = Rng::new(1);
    let mut model = vgg_small(
        &VggConfig { hw: cfg.hw, width_mult: cfg.width_mult, ..Default::default() },
        &mut rng,
    );
    let _trainer = ClassifierTrainer::new(&cfg);
    let mut store = crate::nn::ParamStore::new();
    let mut sampler = crate::data::BatchSampler::new(train.n, cfg.batch, 1);
    let mut ratios = Vec::new();
    for step in 0..cfg.steps {
        let idx = sampler.next_batch();
        let (x, labels) = train.batch(&idx);
        let logits = model.forward(Value::F32(x), true).expect_f32("fig4");
        let out = crate::nn::softmax_cross_entropy(&logits, &labels);
        store.zero_grads();
        let g_in = model.backward(out.grad, &mut store);
        // statistics of the upstream-most signal
        let mean = g_in.mean();
        let var = g_in.data.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>()
            / g_in.len() as f32;
        let ratio = mean.abs() / var.sqrt().max(1e-12);
        ratios.push(ratio);
        let mut params = model.params();
        let bool_opt = BooleanOptimizer::new(cfg.lr_bool);
        bool_opt.step(&mut params, &mut store);
        if step % 10 == 0 {
            println!("step {step:>4}: |mean|/sigma = {ratio:.4}");
        }
    }
    let avg: f32 = ratios.iter().sum::<f32>() / ratios.len() as f32;
    println!("average over training: {avg:.4}  (paper Fig. 4: ≈ 0.01–0.1 ≪ 1)");
    Ok(())
}

/// Tables 14/15: hardware constants as encoded in the energy model.
pub fn hw_tables() -> Result<(), String> {
    for hw in [crate::energy::ASCEND(), crate::energy::V100()] {
        println!("--- {} memory hierarchy", hw.name);
        println!("{:<8} {:>16} {:>14}", "level", "capacity", "pJ/byte");
        for l in &hw.levels {
            let cap = if l.capacity == usize::MAX {
                "unbounded".to_string()
            } else {
                format!("{} KiB", l.capacity / 1024)
            };
            println!("{:<8} {:>16} {:>14.4}", l.name, cap, l.pj_per_byte);
        }
        println!(
            "FP32 MAC {:.4} pJ, Boolean logic op {:.5} pJ",
            hw.pj_per_mac_fp32, hw.pj_per_logic_op
        );
    }
    Ok(())
}

/// Theorem 3.16 — empirical convergence of the Boolean optimizer on a
/// smooth non-convex objective: the running mean of ‖∇f(w_t)‖² decays
/// like A/T then saturates at the discretization floor L·r_d.
pub fn convergence(quick: bool) -> Result<(), String> {
    println!("Theorem 3.16 — empirical ‖∇f(w)‖² trace under Boolean optimization");
    // f(w) = Σ_i (1 − w_i·p_i)²/d + 0.5·Σ_{i<j близко} w_i w_j c_ij/d:
    // smooth, non-convex in the ±1 relaxation, with planted optimum p.
    let d = 256usize;
    let t_max = if quick { 200 } else { 1000 };
    let mut rng = Rng::new(5);
    let p: Vec<f32> = (0..d).map(|_| rng.sign()).collect();
    let mut bits = BitMatrix::random(1, d, &mut rng);
    let mut grad = Tensor::zeros(&[1, d]);
    let mut store = ParamStore::new();
    let opt = BooleanOptimizer::new(0.3).with_clip(2.0);
    let grad_f = |w: &[f32], g: &mut [f32], rng: &mut Rng| -> f32 {
        // stochastic gradient: planted quadratic + noise (A.3's σ²)
        let mut norm = 0.0;
        for i in 0..w.len() {
            let gi = -2.0 * p[i] * (1.0 - w[i] * p[i]) / d as f32;
            g[i] = gi + 0.05 * rng.normal() / d as f32;
            norm += gi * gi;
        }
        norm
    };
    let mut running = Vec::new();
    for t in 0..t_max {
        let w: Vec<f32> = (0..d).map(|i| bits.pm1(0, i)).collect();
        let gnorm = grad_f(&w, &mut grad.data, &mut rng);
        // descent direction: votes = −gradient (the optimizer flips where
        // vote aligns with w)
        for v in grad.data.iter_mut() {
            *v = -*v * d as f32; // scale to vote magnitude
        }
        store.zero_grads();
        store.accumulate("w", &grad);
        let mut params = vec![ParamRef::Bool { name: "w".into(), bits: &mut bits }];
        opt.step(&mut params, &mut store);
        running.push(gnorm);
        if t % (t_max / 10).max(1) == 0 {
            let avg: f32 = running.iter().sum::<f32>() / running.len() as f32;
            println!("T {t:>5}: (1/T)Σ‖∇f‖² = {avg:.6}");
        }
    }
    let early: f32 = running[..t_max / 10].iter().sum::<f32>() / (t_max / 10) as f32;
    let late: f32 =
        running[t_max - t_max / 10..].iter().sum::<f32>() / (t_max / 10) as f32;
    let agree = (0..d).filter(|&i| bits.pm1(0, i) == p[i]).count();
    println!(
        "early avg {early:.6} → late avg {late:.6}; planted-optimum agreement {agree}/{d}"
    );
    println!("(Theorem 3.16: 1/T decay down to the discrete floor L·r_d — no divergence)");
    Ok(())
}

#[cfg(test)]
mod tests {
    #[test]
    fn table8_and_fig5_run() {
        super::table8().unwrap();
        super::fig5().unwrap();
        super::hw_tables().unwrap();
    }

    #[test]
    fn convergence_quick_runs() {
        super::convergence(true).unwrap();
    }
}
