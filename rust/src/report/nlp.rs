//! Table 7: Boolean BERT on the GLUE-like synthetic suite.

use crate::data::{GlueLikeTask, NlpDataset};
use crate::models::bert::{BertConfig, BertMini};
use crate::nn::softmax_cross_entropy;
use crate::optim::{Adam, BooleanOptimizer, CosineSchedule};
use crate::util::Rng;

/// Train one model on one task; returns validation accuracy (%).
fn train_task(task: GlueLikeTask, boolean: bool, quick: bool, seed: u64) -> f32 {
    let (n_train, steps) = if quick { (256, 80) } else { (1024, 400) };
    let len = 12;
    let vocab = 32;
    let train = NlpDataset::generate(task, n_train, len, vocab, seed);
    let val = NlpDataset::generate(task, 256, len, vocab, seed + 1);
    let cfg = BertConfig {
        vocab,
        max_len: len,
        d: if boolean { 24 } else { 24 },
        ff: 48,
        layers: 2,
        classes: 2,
    };
    let mut rng = Rng::new(seed);
    let mut model = BertMini::new(&cfg, &mut rng);
    let sched = CosineSchedule::new(if boolean { 1.0 } else { 0.0 }, 0.0, steps);
    let mut adam = Adam::new(2e-3);
    let mut store = crate::nn::ParamStore::new();
    let batch = 32;
    let mut sampler = crate::data::BatchSampler::new(train.n, batch, seed);
    for step in 0..steps {
        let idx = sampler.next_batch();
        let (toks, labels) = train.batch(&idx);
        let logits = model.forward(&toks, idx.len(), len, true);
        let out = softmax_cross_entropy(&logits, &labels);
        store.zero_grads();
        model.backward(out.grad, &mut store);
        let mut params = model.params();
        if boolean {
            BooleanOptimizer::new(sched.at(step)).step(&mut params, &mut store);
        }
        adam.step(&mut params, &mut store);
    }
    // evaluate
    let idx: Vec<usize> = (0..val.n).collect();
    let (toks, labels) = val.batch(&idx);
    let logits = model.forward(&toks, val.n, len, false);
    let preds = logits.argmax_rows();
    let correct = preds.iter().zip(&labels).filter(|(p, l)| p == l).count();
    correct as f32 / val.n as f32 * 100.0
}

/// Table 7: per-task accuracy, Boolean BERT vs "FP teacher" reference.
///
/// Note on the FP row: the same BertMini with Boolean projections *not*
/// optimized (frozen random Boolean weights, FP rest) is the ablation
/// lower bound; the upper reference keeps all-FP projections out of scope
/// for the scaled run, so we compare Boolean-trained vs Boolean-frozen to
/// isolate what Boolean-logic training contributes.
pub fn table7(quick: bool) -> Result<(), String> {
    println!("Table 7 — Boolean BERT-mini on GLUE-like synthetic tasks (accuracy %)");
    println!(
        "{:<14} {:>22} {:>26}",
        "task", "B⊕LD BERT (trained)", "frozen-Boolean ablation"
    );
    let mut sum_b = 0.0;
    let mut sum_f = 0.0;
    let tasks: Vec<GlueLikeTask> = if quick {
        vec![GlueLikeTask::Sentiment, GlueLikeTask::Paraphrase]
    } else {
        GlueLikeTask::all().to_vec()
    };
    let ntasks = tasks.len() as f32;
    for task in tasks {
        let acc_bold = train_task(task, true, quick, 42);
        let acc_frozen = train_task(task, false, quick, 42);
        sum_b += acc_bold;
        sum_f += acc_frozen;
        println!("{:<14} {:>22.1} {:>26.1}", task.name(), acc_bold, acc_frozen);
    }
    println!(
        "{:<14} {:>22.1} {:>26.1}",
        "Avg.",
        sum_b / ntasks,
        sum_f / ntasks
    );
    println!("(paper: B⊕LD avg 70.9 vs BiT 71.0, BiBERT 63.2 — Boolean training ≈ SOTA binarized)");
    Ok(())
}
