//! Report harness: regenerate every table and figure of the paper's
//! evaluation (§4 + Appendices C/D/E) on the scaled workloads documented
//! in DESIGN.md §2. Invoked as `bold report <artifact>`.
//!
//! Absolute numbers are testbed-scaled; what must (and does) reproduce is
//! the *shape*: which method wins, by roughly what factor, where the
//! crossovers fall. EXPERIMENTS.md records paper-vs-measured per artifact.

mod classification;
mod dense;
mod mathrep;
mod nlp;

pub use classification::{fig1, table10, table2, table5, table6, table9};
pub use dense::{table12, table13, table3, table4};
pub use mathrep::{convergence, fig4, fig5, hw_tables, table8};
pub use nlp::table7;

/// All report ids, in paper order.
pub const ALL_REPORTS: &[&str] = &[
    "fig1", "table2", "table3", "table4", "table5", "table6", "table7", "table8", "table9",
    "table10", "table12", "table13", "fig4", "fig5", "hw", "convergence",
];

/// Dispatch a report by id. `quick` shrinks workloads for CI/smoke runs.
pub fn run(id: &str, quick: bool) -> Result<(), String> {
    match id {
        "fig1" => fig1(quick),
        "table2" => table2(quick),
        "table3" => table3(quick),
        "table4" => table4(quick),
        "table5" => table5(quick),
        "table6" => table6(quick),
        "table7" => table7(quick),
        "table8" => table8(),
        "table9" => table9(quick),
        "table10" => table10(quick),
        "table12" => table12(quick),
        "table13" => table13(quick),
        "fig4" => fig4(quick),
        "fig5" => fig5(),
        "hw" => hw_tables(),
        "convergence" => convergence(quick),
        "all" => {
            for r in ALL_REPORTS {
                println!("\n================ {r} ================");
                run(r, quick)?;
            }
            Ok(())
        }
        other => Err(format!("unknown report '{other}'; available: {ALL_REPORTS:?} or 'all'")),
    }
}
