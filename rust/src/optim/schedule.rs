//! Cosine learning-rate schedule (the paper's choice, Appendix D.1.1:
//! "both optimizers used the cosine scheduler").

/// lr(t) = lr_min + (lr_max − lr_min)·(1 + cos(π·t/T))/2
#[derive(Debug, Clone, Copy)]
pub struct CosineSchedule {
    pub lr_max: f32,
    pub lr_min: f32,
    pub total_steps: usize,
}

impl CosineSchedule {
    pub fn new(lr_max: f32, lr_min: f32, total_steps: usize) -> Self {
        CosineSchedule { lr_max, lr_min, total_steps }
    }

    pub fn at(&self, step: usize) -> f32 {
        if self.total_steps == 0 {
            return self.lr_max;
        }
        let p = (step.min(self.total_steps) as f32) / self.total_steps as f32;
        self.lr_min
            + (self.lr_max - self.lr_min) * 0.5 * (1.0 + (std::f32::consts::PI * p).cos())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn endpoints_and_midpoint() {
        let s = CosineSchedule::new(10.0, 1.0, 100);
        assert!((s.at(0) - 10.0).abs() < 1e-5);
        assert!((s.at(100) - 1.0).abs() < 1e-5);
        assert!((s.at(50) - 5.5).abs() < 1e-4);
    }

    #[test]
    fn monotone_decreasing() {
        let s = CosineSchedule::new(3.0, 0.0, 10);
        for t in 0..10 {
            assert!(s.at(t) >= s.at(t + 1));
        }
    }

    #[test]
    fn clamps_past_total() {
        let s = CosineSchedule::new(5.0, 0.5, 10);
        assert_eq!(s.at(50), s.at(10));
    }
}
