//! Plain SGD with momentum — used by some BNN baseline recipes.

use crate::nn::{ParamRef, ParamStore};

/// SGD hyper-parameters; the velocity buffer is kept per-parameter in the
/// optimizer (baselines don't checkpoint mid-run), gradients are read
/// from the [`ParamStore`].
pub struct Sgd {
    pub lr: f32,
    pub momentum: f32,
    state: std::collections::HashMap<String, Vec<f32>>,
}

impl Sgd {
    pub fn new(lr: f32, momentum: f32) -> Self {
        Sgd { lr, momentum, state: std::collections::HashMap::new() }
    }

    pub fn step(&mut self, params: &mut [ParamRef<'_>], store: &ParamStore) {
        for p in params.iter_mut() {
            if let ParamRef::Real { name, w } = p {
                let Some(grad) = store.grad(name) else { continue };
                let n = w.len();
                debug_assert_eq!(grad.len(), n, "{name}: grad/weight size");
                let v = self.state.entry(name.clone()).or_insert_with(|| vec![0.0; n]);
                for i in 0..n {
                    v[i] = self.momentum * v[i] + grad.data[i];
                    w.data[i] -= self.lr * v[i];
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Tensor;

    #[test]
    fn sgd_descends() {
        let mut w = Tensor::from_vec(&[1], vec![10.0]);
        let mut store = ParamStore::new();
        let mut opt = Sgd::new(0.1, 0.9);
        for _ in 0..100 {
            store.zero_grads();
            store.accumulate("w", &Tensor::from_vec(&[1], vec![2.0 * w.data[0]]));
            let mut params = vec![ParamRef::Real { name: "w".into(), w: &mut w }];
            opt.step(&mut params, &store);
        }
        assert!(w.data[0].abs() < 0.1);
    }
}
