//! Adam (Kingma & Ba) for the FP parameters — the paper trains first/last
//! FP layers and BN with Adam at lr 1e-3 (§4 / Appendix D.1.1).

use crate::nn::{ParamRef, ParamStore};

/// Adam hyper-parameters. The per-parameter moments and the shared
/// timestep live in the [`ParamStore`] (keyed by parameter name), so a
/// checkpointed store resumes training bit-exactly with a fresh `Adam`.
pub struct Adam {
    pub lr: f32,
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    pub weight_decay: f32,
}

impl Adam {
    pub fn new(lr: f32) -> Self {
        Adam { lr, beta1: 0.9, beta2: 0.999, eps: 1e-8, weight_decay: 0.0 }
    }

    pub fn with_weight_decay(mut self, wd: f32) -> Self {
        self.weight_decay = wd;
        self
    }

    /// Apply one step to every `ParamRef::Real` (Bool params are ignored —
    /// they belong to the Boolean optimizer), reading gradients from and
    /// keeping moments in `store`.
    pub fn step(&mut self, params: &mut [ParamRef<'_>], store: &mut ParamStore) {
        store.adam_t += 1;
        let t = store.adam_t as f32;
        let bc1 = 1.0 - self.beta1.powf(t);
        let bc2 = 1.0 - self.beta2.powf(t);
        for p in params.iter_mut() {
            if let ParamRef::Real { name, w } = p {
                let n = w.len();
                if n == 0 {
                    continue;
                }
                let slot = store.slot_mut(name);
                slot.grad_mut(&w.shape); // zeros if this param got no gradient
                slot.adam_mut(n);
                debug_assert_eq!(slot.grad.len(), n, "{name}: grad/weight size");
                let grad = &slot.grad.data;
                let m = &mut slot.adam_m;
                let v = &mut slot.adam_v;
                for i in 0..n {
                    let mut g = grad[i];
                    if self.weight_decay != 0.0 {
                        g += self.weight_decay * w.data[i];
                    }
                    m[i] = self.beta1 * m[i] + (1.0 - self.beta1) * g;
                    v[i] = self.beta2 * v[i] + (1.0 - self.beta2) * g * g;
                    let mhat = m[i] / bc1;
                    let vhat = v[i] / bc2;
                    w.data[i] -= self.lr * mhat / (vhat.sqrt() + self.eps);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Tensor;

    #[test]
    fn adam_minimizes_quadratic() {
        // minimize ||w − target||² with analytic gradient
        let mut w = Tensor::from_vec(&[4], vec![5.0, -3.0, 2.0, 0.0]);
        let target = [1.0f32, 1.0, 1.0, 1.0];
        let mut store = ParamStore::new();
        let mut opt = Adam::new(0.1);
        for _ in 0..300 {
            let mut grad = Tensor::zeros(&[4]);
            for i in 0..4 {
                grad.data[i] = 2.0 * (w.data[i] - target[i]);
            }
            store.zero_grads();
            store.accumulate("w", &grad);
            let mut params = vec![ParamRef::Real { name: "w".into(), w: &mut w }];
            opt.step(&mut params, &mut store);
        }
        for i in 0..4 {
            assert!((w.data[i] - target[i]).abs() < 1e-2, "w[{i}] = {}", w.data[i]);
        }
    }

    #[test]
    fn first_step_is_lr_sized() {
        // Adam's first update has magnitude ≈ lr regardless of grad scale.
        let mut w = Tensor::from_vec(&[1], vec![0.0]);
        let mut store = ParamStore::new();
        store.accumulate("w", &Tensor::from_vec(&[1], vec![1234.0]));
        let mut opt = Adam::new(0.01);
        let mut params = vec![ParamRef::Real { name: "w".into(), w: &mut w }];
        opt.step(&mut params, &mut store);
        assert!((w.data[0] + 0.01).abs() < 1e-4, "{}", w.data[0]);
    }

    #[test]
    fn moments_and_timestep_live_in_store() {
        let mut w = Tensor::from_vec(&[1], vec![0.0]);
        let mut store = ParamStore::new();
        let mut opt = Adam::new(0.1);
        for _ in 0..3 {
            store.zero_grads();
            store.accumulate("same", &Tensor::from_vec(&[1], vec![1.0]));
            let mut params = vec![ParamRef::Real { name: "same".into(), w: &mut w }];
            opt.step(&mut params, &mut store);
        }
        assert_eq!(store.adam_t, 3);
        let slot = store.slot("same").unwrap();
        assert_eq!(slot.adam_m.len(), 1);
        assert!(slot.adam_m[0] > 0.0 && slot.adam_v[0] > 0.0);
        // a fresh Adam over the same store continues the trajectory
        let w_before = w.data[0];
        let mut opt2 = Adam::new(0.1);
        store.zero_grads();
        store.accumulate("same", &Tensor::from_vec(&[1], vec![1.0]));
        let mut params = vec![ParamRef::Real { name: "same".into(), w: &mut w }];
        opt2.step(&mut params, &mut store);
        assert_eq!(store.adam_t, 4);
        assert!(w.data[0] < w_before, "step continued from stored moments");
    }
}
