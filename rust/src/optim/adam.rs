//! Adam (Kingma & Ba) for the FP parameters — the paper trains first/last
//! FP layers and BN with Adam at lr 1e-3 (§4 / Appendix D.1.1).

use crate::nn::ParamRef;

/// Adam with per-parameter state kept by parameter *name* (layer names are
/// stable across steps, so the state follows the parameter even if the
/// collection order changes).
pub struct Adam {
    pub lr: f32,
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    pub weight_decay: f32,
    t: u64,
    state: std::collections::HashMap<String, (Vec<f32>, Vec<f32>)>,
}

impl Adam {
    pub fn new(lr: f32) -> Self {
        Adam {
            lr,
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            weight_decay: 0.0,
            t: 0,
            state: std::collections::HashMap::new(),
        }
    }

    pub fn with_weight_decay(mut self, wd: f32) -> Self {
        self.weight_decay = wd;
        self
    }

    /// Apply one step to every `ParamRef::Real` (Bool params are ignored —
    /// they belong to the Boolean optimizer).
    pub fn step(&mut self, params: &mut [ParamRef<'_>]) {
        self.t += 1;
        let t = self.t as f32;
        let bc1 = 1.0 - self.beta1.powf(t);
        let bc2 = 1.0 - self.beta2.powf(t);
        for p in params.iter_mut() {
            if let ParamRef::Real { name, w, grad } = p {
                let n = w.len();
                let (m, v) = self
                    .state
                    .entry(name.clone())
                    .or_insert_with(|| (vec![0.0; n], vec![0.0; n]));
                assert_eq!(m.len(), n, "param {name} changed size");
                for i in 0..n {
                    let mut g = grad.data[i];
                    if self.weight_decay != 0.0 {
                        g += self.weight_decay * w.data[i];
                    }
                    m[i] = self.beta1 * m[i] + (1.0 - self.beta1) * g;
                    v[i] = self.beta2 * v[i] + (1.0 - self.beta2) * g * g;
                    let mhat = m[i] / bc1;
                    let vhat = v[i] / bc2;
                    w.data[i] -= self.lr * mhat / (vhat.sqrt() + self.eps);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Tensor;

    #[test]
    fn adam_minimizes_quadratic() {
        // minimize ||w − target||² with analytic gradient
        let mut w = Tensor::from_vec(&[4], vec![5.0, -3.0, 2.0, 0.0]);
        let target = [1.0f32, 1.0, 1.0, 1.0];
        let mut grad = Tensor::zeros(&[4]);
        let mut opt = Adam::new(0.1);
        for _ in 0..300 {
            for i in 0..4 {
                grad.data[i] = 2.0 * (w.data[i] - target[i]);
            }
            let mut params = vec![ParamRef::Real { name: "w".into(), w: &mut w, grad: &mut grad }];
            opt.step(&mut params);
        }
        for i in 0..4 {
            assert!((w.data[i] - target[i]).abs() < 1e-2, "w[{i}] = {}", w.data[i]);
        }
    }

    #[test]
    fn first_step_is_lr_sized() {
        // Adam's first update has magnitude ≈ lr regardless of grad scale.
        let mut w = Tensor::from_vec(&[1], vec![0.0]);
        let mut grad = Tensor::from_vec(&[1], vec![1234.0]);
        let mut opt = Adam::new(0.01);
        let mut params = vec![ParamRef::Real { name: "w".into(), w: &mut w, grad: &mut grad }];
        opt.step(&mut params);
        assert!((w.data[0] + 0.01).abs() < 1e-4, "{}", w.data[0]);
    }

    #[test]
    fn state_follows_name() {
        let mut w = Tensor::from_vec(&[1], vec![0.0]);
        let mut grad = Tensor::from_vec(&[1], vec![1.0]);
        let mut opt = Adam::new(0.1);
        for _ in 0..3 {
            let mut params =
                vec![ParamRef::Real { name: "same".into(), w: &mut w, grad: &mut grad }];
            opt.step(&mut params);
        }
        assert_eq!(opt.state.len(), 1);
        assert_eq!(opt.t, 3);
    }
}
