//! The Boolean optimizer of §3.3 (Eq. 9–11, Algorithms 1/2/8).
//!
//! Per Boolean parameter tensor it keeps (in the [`ParamStore`]) an
//! accumulator m (Eq. 10) and the auto-regularizing factor β = fraction of
//! unchanged weights (Eq. 11, per-layer basis as in the paper's
//! experiments). One step:
//!
//!   m ← β·m + η·q              (q = aggregated vote, Eq. 7)
//!   flip w where  m·e(w) ≥ 1   (xnor(m, w) = T with |m| ≥ 1 — Eq. 9)
//!   m ← 0 at flipped positions
//!   β ← 1 − (#flips / #weights)
//!
//! The flip rule reads: if the accumulated loss-variation w.r.t. w has the
//! same sign as w itself, then flipping w decreases the loss — the purely
//! logical counterpart of "step against the gradient".
//!
//! # Word-parallel kernel
//!
//! Flips are applied on the *packed* representation: the accumulator scan
//! of one 64-lane word builds a 64-bit flip mask, then a single
//! `words[i] ^= mask` commits all of that word's flips at once — the
//! dataflow the paper's energy analysis (§5) assumes, instead of per-bit
//! `get`/`flip` calls. The 64-lane scan itself runs on the
//! runtime-dispatched SIMD backend (`crate::tensor::simd`, DESIGN.md
//! §SIMD-Backend). For large tensors, disjoint row ranges shard across
//! the persistent [`crate::util::pool`] (DESIGN.md §Parallelism) — no
//! per-call thread spawning. The per-element arithmetic (and therefore
//! the result) is bit-identical to the scalar rule; only the write path
//! is word-granular.

use crate::nn::{ParamRef, ParamStore};
use crate::util::pool;

/// Minimum weights per pool shard (~256 Ki lanes ≈ 100s of µs of scan):
/// shard count scales with the WORK, so tensors that would give a shard
/// less work than the enqueue/wakeup overhead stay on the sequential
/// path. The shard cap itself (thread budget, row count) lives in
/// [`pool::shards_for`].
const PAR_QUANTUM: usize = 1 << 18;

/// Flip statistics for one step (for logging / Fig. 4-style diagnostics).
#[derive(Debug, Clone, Copy, Default)]
pub struct FlipStats {
    pub flips: usize,
    pub total: usize,
}

impl FlipStats {
    pub fn flip_rate(&self) -> f32 {
        if self.total == 0 { 0.0 } else { self.flips as f32 / self.total as f32 }
    }
}

/// Boolean optimizer with a tunable accumulation rate η. Stateless: the
/// accumulator m and ratio β live in the [`ParamStore`].
///
/// ```
/// use bold::nn::{ParamRef, ParamStore};
/// use bold::optim::BooleanOptimizer;
/// use bold::tensor::{BitMatrix, Tensor};
///
/// // One 1×2 Boolean weight tensor: w = [T, F] in the ±1 embedding.
/// let mut bits = BitMatrix::zeros(1, 2);
/// bits.set(0, 0, true);
/// let mut store = ParamStore::new();
/// store.accumulate("w", &Tensor::from_vec(&[1, 2], vec![1.0, 1.0])); // votes q
///
/// let opt = BooleanOptimizer::new(1.0); // η = 1
/// let mut params = vec![ParamRef::Bool { name: "w".into(), bits: &mut bits }];
/// let stats = opt.step(&mut params, &mut store);
///
/// // Eq. (9): w₀ = T agrees with its vote ⇒ flipped; w₁ = F does not.
/// assert_eq!(stats.flips, 1);
/// assert!(!bits.get(0, 0) && !bits.get(0, 1));
/// ```
pub struct BooleanOptimizer {
    pub lr: f32,
    /// Optional |m| clip (κ of assumption A.5 in the convergence proof).
    pub clip: Option<f32>,
}

impl BooleanOptimizer {
    pub fn new(lr: f32) -> Self {
        BooleanOptimizer { lr, clip: None }
    }

    pub fn with_clip(mut self, kappa: f32) -> Self {
        self.clip = Some(kappa);
        self
    }

    /// Apply one step to every `ParamRef::Bool` in `params` (others are
    /// ignored — they belong to the FP optimizer), reading votes from and
    /// updating accumulator state in `store`.
    pub fn step(&self, params: &mut [ParamRef<'_>], store: &mut ParamStore) -> FlipStats {
        let mut stats = FlipStats::default();
        for p in params.iter_mut() {
            if let ParamRef::Bool { name, bits } = p {
                let rows = bits.rows;
                let cols = bits.cols;
                let total = rows * cols;
                if total == 0 {
                    continue;
                }
                let slot = store.slot_mut(name);
                // A param that never received votes still decays its
                // accumulator (grad ≡ 0), matching the scalar rule.
                slot.grad_mut(&[rows, cols]);
                slot.accum_mut(total);
                debug_assert_eq!(slot.grad.len(), total, "{name}: vote/weight size");
                let beta = slot.ratio;
                let flips = step_one(
                    self.lr,
                    self.clip,
                    &mut **bits,
                    &slot.grad.data,
                    &mut slot.accum.data,
                    beta,
                );
                slot.ratio = 1.0 - flips as f32 / total.max(1) as f32; // Eq. (11)
                stats.flips += flips;
                stats.total += total;
            }
        }
        stats
    }
}

/// One tensor's flip pass: returns the number of flips. Shards disjoint
/// row ranges across the persistent pool when the tensor is large enough
/// (no per-call thread spawning).
fn step_one(
    lr: f32,
    clip: Option<f32>,
    bits: &mut crate::tensor::BitMatrix,
    grad: &[f32],
    accum: &mut [f32],
    beta: f32,
) -> usize {
    let rows = bits.rows;
    let cols = bits.cols;
    let wpr = bits.wpr;
    let shards = pool::shards_for(rows * cols, rows, PAR_QUANTUM);
    if shards <= 1 {
        return step_rows(lr, clip, &mut bits.words, grad, accum, beta, cols, wpr);
    }
    let rows_per = rows.div_ceil(shards);
    let mut counts = vec![0usize; rows.div_ceil(rows_per)];
    {
        let mut tasks = Vec::with_capacity(counts.len());
        let mut counts_rest: &mut [usize] = &mut counts;
        let mut words_rest: &mut [u64] = &mut bits.words;
        let mut grad_rest: &[f32] = grad;
        let mut accum_rest: &mut [f32] = accum;
        let mut row = 0usize;
        while row < rows {
            let take = rows_per.min(rows - row);
            let (w_chunk, w_rem) = words_rest.split_at_mut(take * wpr);
            let (g_chunk, g_rem) = grad_rest.split_at(take * cols);
            let (a_chunk, a_rem) = accum_rest.split_at_mut(take * cols);
            let (c_slot, c_rem) = counts_rest.split_at_mut(1);
            words_rest = w_rem;
            grad_rest = g_rem;
            accum_rest = a_rem;
            counts_rest = c_rem;
            tasks.push(move || {
                c_slot[0] = step_rows(lr, clip, w_chunk, g_chunk, a_chunk, beta, cols, wpr);
            });
            row += take;
        }
        pool::run_scoped(tasks);
    }
    counts.iter().sum()
}

/// Scalar-exact scan over a contiguous block of rows, committing flips
/// with one XOR mask per packed word. The per-word 64-lane scan (Eq.
/// 9–10: `m ← β·m + η·q`, clamp, compare against the packed sign) runs
/// on the dispatched SIMD backend's `flip_scan_word`
/// ([`crate::tensor::simd`]) — 8 f32 lanes per AVX2 vector with the
/// scalar rule's exact IEEE operation order, so the result is
/// bit-identical on every backend.
#[allow(clippy::too_many_arguments)]
fn step_rows(
    lr: f32,
    clip: Option<f32>,
    words: &mut [u64],
    grad: &[f32],
    accum: &mut [f32],
    beta: f32,
    cols: usize,
    wpr: usize,
) -> usize {
    let rows = if wpr == 0 { 0 } else { words.len() / wpr };
    let scan = crate::tensor::simd::kernels().flip_scan_word;
    let mut flips = 0usize;
    for r in 0..rows {
        for wi in 0..wpr {
            let lanes = 64.min(cols - wi * 64);
            let word = &mut words[r * wpr + wi];
            let base = r * cols + wi * 64;
            let mask = scan(
                *word,
                &grad[base..base + lanes],
                &mut accum[base..base + lanes],
                beta,
                lr,
                clip,
            );
            *word ^= mask; // commit all of this word's flips at once
            flips += mask.count_ones() as usize;
        }
    }
    flips
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::ParamStore;
    use crate::tensor::{BitMatrix, Tensor};
    use crate::util::Rng;

    fn store_with(name: &str, grad: &Tensor) -> ParamStore {
        let mut s = ParamStore::new();
        s.accumulate(name, grad);
        s
    }

    #[test]
    fn flip_rule_eq9_semantics() {
        // q aligned with w and |η·q| ≥ 1 ⇒ flip; opposite sign ⇒ no flip.
        let mut bits = BitMatrix::zeros(1, 2);
        bits.set(0, 0, true); // w0 = +1
        bits.set(0, 1, false); // w1 = −1
        let grad = Tensor::from_vec(&[1, 2], vec![1.0, 1.0]);
        let mut store = store_with("w", &grad);
        let opt = BooleanOptimizer::new(1.0);
        let mut params = vec![ParamRef::Bool { name: "w".into(), bits: &mut bits }];
        let stats = opt.step(&mut params, &mut store);
        assert_eq!(stats.flips, 1);
        assert!(!bits.get(0, 0), "w0 flipped to F");
        assert!(!bits.get(0, 1), "w1 unchanged");
        let slot = store.slot("w").unwrap();
        assert_eq!(slot.accum.data[0], 0.0, "flipped accumulator reset");
        assert_eq!(slot.accum.data[1], 1.0, "unflipped accumulates η·q");
        assert!((slot.ratio - 0.5).abs() < 1e-6, "β = 1 − 1/2");
    }

    #[test]
    fn small_votes_accumulate_until_threshold() {
        let mut bits = BitMatrix::zeros(1, 1);
        bits.set(0, 0, true);
        let grad = Tensor::from_vec(&[1, 1], vec![0.4]); // η·q = 0.4, same sign as w
        let mut store = store_with("w", &grad);
        let opt = BooleanOptimizer::new(1.0);
        for step in 0..3 {
            let mut params = vec![ParamRef::Bool { name: "w".into(), bits: &mut bits }];
            let stats = opt.step(&mut params, &mut store);
            if step < 2 {
                assert_eq!(stats.flips, 0, "no flip until |m| ≥ 1 (step {step})");
            } else {
                // m = 0.4, 0.8, 1.2 (β stays 1 while nothing flips)
                assert_eq!(stats.flips, 1, "flip at step {step}");
            }
        }
        assert!(!bits.get(0, 0));
    }

    #[test]
    fn beta_damps_stale_accumulation() {
        // After a step with many flips, β < 1 shrinks old accumulator mass.
        let mut rng = Rng::new(3);
        let mut bits = BitMatrix::random(8, 8, &mut rng);
        let before = bits.clone();
        let mut grad = Tensor::zeros(&[8, 8]);
        // strong votes aligned with every weight ⇒ all flip
        for r in 0..8 {
            for c in 0..8 {
                grad.data[r * 8 + c] = if before.get(r, c) { 2.0 } else { -2.0 };
            }
        }
        let mut store = store_with("w", &grad);
        let opt = BooleanOptimizer::new(1.0);
        let mut params = vec![ParamRef::Bool { name: "w".into(), bits: &mut bits }];
        let stats = opt.step(&mut params, &mut store);
        assert_eq!(stats.flips, 64);
        assert_eq!(store.slot("w").unwrap().ratio, 0.0, "β = 0 after everything flipped");
        assert_eq!(bits.hamming(&before), 64);
    }

    #[test]
    fn clip_bounds_accumulator() {
        let mut bits = BitMatrix::zeros(1, 1); // w = −1; positive votes never flip it
        let grad = Tensor::from_vec(&[1, 1], vec![10.0]);
        let mut store = store_with("w", &grad);
        let opt = BooleanOptimizer::new(1.0).with_clip(2.5);
        for _ in 0..5 {
            let mut params = vec![ParamRef::Bool { name: "w".into(), bits: &mut bits }];
            opt.step(&mut params, &mut store);
        }
        let m = store.slot("w").unwrap().accum.data[0];
        assert!(m <= 2.5, "A.5 bound respected: {m}");
    }

    #[test]
    fn zero_grad_never_flips() {
        let mut rng = Rng::new(5);
        let mut bits = BitMatrix::random(16, 16, &mut rng);
        let before = bits.clone();
        let mut store = store_with("w", &Tensor::zeros(&[16, 16]));
        let opt = BooleanOptimizer::new(100.0);
        let mut params = vec![ParamRef::Bool { name: "w".into(), bits: &mut bits }];
        let stats = opt.step(&mut params, &mut store);
        assert_eq!(stats.flips, 0);
        assert_eq!(bits, before);
    }

    #[test]
    fn unvoted_param_decays_but_does_not_flip() {
        // A Bool param with no accumulate() call at all still steps (grad
        // treated as zeros): accumulator decays by β, nothing flips.
        let mut rng = Rng::new(6);
        let mut bits = BitMatrix::random(4, 4, &mut rng);
        let before = bits.clone();
        let mut store = ParamStore::new();
        store.slot_mut("w").accum_mut(16).data[0] = 0.5;
        store.slot_mut("w").ratio = 0.5;
        let opt = BooleanOptimizer::new(1.0);
        let mut params = vec![ParamRef::Bool { name: "w".into(), bits: &mut bits }];
        let stats = opt.step(&mut params, &mut store);
        assert_eq!(stats.flips, 0);
        assert_eq!(bits, before);
        assert!((store.slot("w").unwrap().accum.data[0] - 0.25).abs() < 1e-6, "m ← β·m");
    }

    /// The word-parallel path (threads + XOR masks) must agree bit-exactly
    /// with a scalar per-bit reference on tail words (cols % 64 ≠ 0) and
    /// on sizes large enough to take the multi-threaded shard path
    /// (1024×520 ≥ 2·PAR_QUANTUM).
    #[test]
    fn word_parallel_matches_scalar_reference() {
        let mut rng = Rng::new(7);
        for (rows, cols) in [(3usize, 70usize), (64, 100), (256, 257), (1024, 520)] {
            let bits0 = BitMatrix::random(rows, cols, &mut rng);
            let grad = Tensor::randn(&[rows, cols], 1.2, &mut rng);
            let accum0 = Tensor::randn(&[rows, cols], 0.8, &mut rng);
            let beta = 0.75f32;
            let lr = 1.0f32;

            // scalar reference (the pre-refactor per-bit rule)
            let mut ref_bits = bits0.clone();
            let mut ref_accum = accum0.clone();
            let mut ref_flips = 0usize;
            for r in 0..rows {
                for c in 0..cols {
                    let idx = r * cols + c;
                    let m = beta * ref_accum.data[idx] + lr * grad.data[idx];
                    let w = if ref_bits.get(r, c) { 1.0 } else { -1.0 };
                    if m * w >= 1.0 {
                        ref_bits.flip(r, c);
                        ref_accum.data[idx] = 0.0;
                        ref_flips += 1;
                    } else {
                        ref_accum.data[idx] = m;
                    }
                }
            }

            // word-parallel path through the public API
            let mut bits = bits0.clone();
            let mut store = ParamStore::new();
            store.accumulate("w", &grad);
            {
                let slot = store.slot_mut("w");
                let a = slot.accum_mut(rows * cols);
                a.data.copy_from_slice(&accum0.data);
                slot.ratio = beta;
            }
            let opt = BooleanOptimizer::new(lr);
            let mut params = vec![ParamRef::Bool { name: "w".into(), bits: &mut bits }];
            let stats = opt.step(&mut params, &mut store);

            assert_eq!(bits, ref_bits, "{rows}x{cols}: packed weights diverge");
            assert_eq!(stats.flips, ref_flips, "{rows}x{cols}: flip count");
            let slot = store.slot("w").unwrap();
            assert_eq!(slot.accum.data, ref_accum.data, "{rows}x{cols}: accumulators");
            let want_beta = 1.0 - ref_flips as f32 / (rows * cols) as f32;
            assert!((slot.ratio - want_beta).abs() < 1e-6);
        }
    }
}
