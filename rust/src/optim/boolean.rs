//! The Boolean optimizer of §3.3 (Eq. 9–11, Algorithms 1/2/8).
//!
//! Per Boolean parameter tensor it keeps an accumulator m (Eq. 10) and the
//! auto-regularizing factor β = fraction of unchanged weights (Eq. 11,
//! per-layer basis as in the paper's experiments). One step:
//!
//!   m ← β·m + η·q              (q = aggregated vote, Eq. 7)
//!   flip w where  m·e(w) ≥ 1   (xnor(m, w) = T with |m| ≥ 1 — Eq. 9)
//!   m ← 0 at flipped positions
//!   β ← 1 − (#flips / #weights)
//!
//! The flip rule reads: if the accumulated loss-variation w.r.t. w has the
//! same sign as w itself, then flipping w decreases the loss — the purely
//! logical counterpart of "step against the gradient".

use crate::nn::ParamRef;

/// Flip statistics for one step (for logging / Fig. 4-style diagnostics).
#[derive(Debug, Clone, Copy, Default)]
pub struct FlipStats {
    pub flips: usize,
    pub total: usize,
}

impl FlipStats {
    pub fn flip_rate(&self) -> f32 {
        if self.total == 0 { 0.0 } else { self.flips as f32 / self.total as f32 }
    }
}

/// Boolean optimizer with a tunable accumulation rate η.
///
/// ```
/// use bold::nn::ParamRef;
/// use bold::optim::BooleanOptimizer;
/// use bold::tensor::{BitMatrix, Tensor};
///
/// // One 1×2 Boolean weight tensor: w = [T, F] in the ±1 embedding.
/// let mut bits = BitMatrix::zeros(1, 2);
/// bits.set(0, 0, true);
/// let mut grad = Tensor::from_vec(&[1, 2], vec![1.0, 1.0]); // votes q
/// let mut accum = Tensor::zeros(&[1, 2]);
/// let mut ratio = 1.0;
///
/// let opt = BooleanOptimizer::new(1.0); // η = 1
/// let mut params = vec![ParamRef::Bool {
///     name: "w".into(),
///     bits: &mut bits,
///     grad: &mut grad,
///     accum: &mut accum,
///     ratio: &mut ratio,
/// }];
/// let stats = opt.step(&mut params);
///
/// // Eq. (9): w₀ = T agrees with its vote ⇒ flipped; w₁ = F does not.
/// assert_eq!(stats.flips, 1);
/// assert!(!bits.get(0, 0) && !bits.get(0, 1));
/// ```
pub struct BooleanOptimizer {
    pub lr: f32,
    /// Optional |m| clip (κ of assumption A.5 in the convergence proof).
    pub clip: Option<f32>,
}

impl BooleanOptimizer {
    pub fn new(lr: f32) -> Self {
        BooleanOptimizer { lr, clip: None }
    }

    pub fn with_clip(mut self, kappa: f32) -> Self {
        self.clip = Some(kappa);
        self
    }

    /// Apply one step to every `ParamRef::Bool` in `params` (others are
    /// ignored — they belong to the FP optimizer).
    pub fn step(&self, params: &mut [ParamRef<'_>]) -> FlipStats {
        let mut stats = FlipStats::default();
        for p in params.iter_mut() {
            if let ParamRef::Bool { bits, grad, accum, ratio, .. } = p {
                let rows = bits.rows;
                let cols = bits.cols;
                debug_assert_eq!(grad.len(), rows * cols);
                let beta: f32 = **ratio;
                let mut flips = 0usize;
                for r in 0..rows {
                    for c in 0..cols {
                        let idx = r * cols + c;
                        // m ← β·m + η·q  (Eq. 10)
                        let mut m = beta * accum.data[idx] + self.lr * grad.data[idx];
                        if let Some(k) = self.clip {
                            m = m.clamp(-k, k);
                        }
                        // Eq. (9): flip when xnor(m, w) = T with |m| ≥ 1.
                        let w = if bits.get(r, c) { 1.0 } else { -1.0 };
                        if m * w >= 1.0 {
                            bits.flip(r, c);
                            accum.data[idx] = 0.0; // reset (Algorithm 1 l.12)
                            flips += 1;
                        } else {
                            accum.data[idx] = m;
                        }
                    }
                }
                let total = rows * cols;
                **ratio = 1.0 - flips as f32 / total.max(1) as f32; // Eq. (11)
                stats.flips += flips;
                stats.total += total;
            }
        }
        stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::{BitMatrix, Tensor};
    use crate::util::Rng;

    fn mk(rows: usize, cols: usize, seed: u64) -> (BitMatrix, Tensor, Tensor, f32) {
        let mut rng = Rng::new(seed);
        (
            BitMatrix::random(rows, cols, &mut rng),
            Tensor::zeros(&[rows, cols]),
            Tensor::zeros(&[rows, cols]),
            1.0,
        )
    }

    #[test]
    fn flip_rule_eq9_semantics() {
        // q aligned with w and |η·q| ≥ 1 ⇒ flip; opposite sign ⇒ no flip.
        let (mut bits, mut grad, mut accum, mut ratio) = mk(1, 2, 1);
        bits.set(0, 0, true); // w0 = +1
        bits.set(0, 1, false); // w1 = −1
        grad.data[0] = 1.0; // same sign as w0 ⇒ flip
        grad.data[1] = 1.0; // opposite sign to w1 ⇒ accumulate
        let opt = BooleanOptimizer::new(1.0);
        let mut params = vec![ParamRef::Bool {
            name: "w".into(),
            bits: &mut bits,
            grad: &mut grad,
            accum: &mut accum,
            ratio: &mut ratio,
        }];
        let stats = opt.step(&mut params);
        assert_eq!(stats.flips, 1);
        assert!(!bits.get(0, 0), "w0 flipped to F");
        assert!(!bits.get(0, 1), "w1 unchanged");
        assert_eq!(accum.data[0], 0.0, "flipped accumulator reset");
        assert_eq!(accum.data[1], 1.0, "unflipped accumulates η·q");
        assert!((ratio - 0.5).abs() < 1e-6, "β = 1 − 1/2");
    }

    #[test]
    fn small_votes_accumulate_until_threshold() {
        let (mut bits, mut grad, mut accum, mut ratio) = mk(1, 1, 2);
        bits.set(0, 0, true);
        grad.data[0] = 0.4; // η·q = 0.4 per step, same sign as w
        let opt = BooleanOptimizer::new(1.0);
        for step in 0..3 {
            let mut params = vec![ParamRef::Bool {
                name: "w".into(),
                bits: &mut bits,
                grad: &mut grad,
                accum: &mut accum,
                ratio: &mut ratio,
            }];
            let stats = opt.step(&mut params);
            if step < 2 {
                assert_eq!(stats.flips, 0, "no flip until |m| ≥ 1 (step {step})");
            } else {
                // m = 0.4, 0.8, 1.2 (β stays 1 while nothing flips)
                assert_eq!(stats.flips, 1, "flip at step {step}");
            }
        }
        assert!(!bits.get(0, 0));
    }

    #[test]
    fn beta_damps_stale_accumulation() {
        // After a step with many flips, β < 1 shrinks old accumulator mass.
        let mut rng = Rng::new(3);
        let mut bits = BitMatrix::random(8, 8, &mut rng);
        let before = bits.clone();
        let mut grad = Tensor::zeros(&[8, 8]);
        // strong votes aligned with every weight ⇒ all flip
        for r in 0..8 {
            for c in 0..8 {
                grad.data[r * 8 + c] = if before.get(r, c) { 2.0 } else { -2.0 };
            }
        }
        let mut accum = Tensor::zeros(&[8, 8]);
        let mut ratio = 1.0;
        let opt = BooleanOptimizer::new(1.0);
        let mut params = vec![ParamRef::Bool {
            name: "w".into(),
            bits: &mut bits,
            grad: &mut grad,
            accum: &mut accum,
            ratio: &mut ratio,
        }];
        let stats = opt.step(&mut params);
        assert_eq!(stats.flips, 64);
        assert_eq!(ratio, 0.0, "β = 0 after everything flipped");
        assert_eq!(bits.hamming(&before), 64);
    }

    #[test]
    fn clip_bounds_accumulator() {
        let (mut bits, mut grad, mut accum, mut ratio) = mk(1, 1, 4);
        bits.set(0, 0, false); // w = −1; positive votes will never flip it
        grad.data[0] = 10.0;
        let opt = BooleanOptimizer::new(1.0).with_clip(2.5);
        for _ in 0..5 {
            let mut params = vec![ParamRef::Bool {
                name: "w".into(),
                bits: &mut bits,
                grad: &mut grad,
                accum: &mut accum,
                ratio: &mut ratio,
            }];
            opt.step(&mut params);
        }
        assert!(accum.data[0] <= 2.5, "A.5 bound respected: {}", accum.data[0]);
    }

    #[test]
    fn zero_grad_never_flips() {
        let (mut bits, mut grad, mut accum, mut ratio) = mk(16, 16, 5);
        let before = bits.clone();
        grad.scale_inplace(0.0);
        let opt = BooleanOptimizer::new(100.0);
        let mut params = vec![ParamRef::Bool {
            name: "w".into(),
            bits: &mut bits,
            grad: &mut grad,
            accum: &mut accum,
            ratio: &mut ratio,
        }];
        let stats = opt.step(&mut params);
        assert_eq!(stats.flips, 0);
        assert_eq!(bits, before);
    }
}
