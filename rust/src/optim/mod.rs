//! Optimizers: the paper's Boolean optimizer (Algorithm 8 + Eqs. 9–11) for
//! native Boolean weights, Adam for the FP layers (the paper's §4 setup),
//! plain SGD for baselines, and a cosine learning-rate schedule.

mod adam;
mod boolean;
mod schedule;
mod sgd;

pub use adam::Adam;
pub use boolean::{BooleanOptimizer, FlipStats};
pub use schedule::CosineSchedule;
pub use sgd::Sgd;
