//! Training-time augmentations (Appendix D.1.1): random crop with
//! reflection padding, horizontal flip, and mixup (Zhang et al. 2018).

use crate::tensor::Tensor;
use crate::util::Rng;

/// Random ±`pad` crop (with edge clamping) and horizontal flip per sample.
pub fn random_crop_flip(x: &Tensor, pad: usize, rng: &mut Rng) -> Tensor {
    let (n, c, h, w) = x.dims4();
    let mut out = Tensor::zeros(&[n, c, h, w]);
    for ni in 0..n {
        let dy = rng.below(2 * pad + 1) as isize - pad as isize;
        let dx = rng.below(2 * pad + 1) as isize - pad as isize;
        let flip = rng.bernoulli(0.5);
        for ci in 0..c {
            let src_plane = (ni * c + ci) * h * w;
            for y in 0..h {
                for xx in 0..w {
                    let sy = (y as isize + dy).clamp(0, h as isize - 1) as usize;
                    let mut sx = (xx as isize + dx).clamp(0, w as isize - 1) as usize;
                    if flip {
                        sx = w - 1 - sx;
                    }
                    out.data[src_plane + y * w + xx] = x.data[src_plane + sy * w + sx];
                }
            }
        }
    }
    out
}

/// Mixup: `x' = λ·x + (1−λ)·x[perm]`; returns (mixed, perm, λ).
/// The caller mixes the loss as `λ·CE(y) + (1−λ)·CE(y[perm])`.
pub fn mixup(x: &Tensor, alpha: f32, rng: &mut Rng) -> (Tensor, Vec<usize>, f32) {
    let n = x.shape[0];
    // Beta(α, α) via two gamma draws would need a gamma sampler; for the
    // common α ≤ 1 regime, a power-of-uniform approximation is adequate:
    // λ = u^α has the right concentration near {0,1} for small α.
    let u = rng.uniform().clamp(1e-3, 1.0 - 1e-3);
    let lam = u.powf(alpha).clamp(0.05, 0.95);
    let mut perm: Vec<usize> = (0..n).collect();
    rng.shuffle(&mut perm);
    let sample = x.len() / n;
    let mut out = x.clone();
    for i in 0..n {
        let j = perm[i];
        for k in 0..sample {
            out.data[i * sample + k] =
                lam * x.data[i * sample + k] + (1.0 - lam) * x.data[j * sample + k];
        }
    }
    (out, perm, lam)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn crop_flip_preserves_shape_and_values() {
        let mut rng = Rng::new(1);
        let x = Tensor::randn(&[4, 3, 8, 8], 1.0, &mut rng);
        let y = random_crop_flip(&x, 2, &mut rng);
        assert_eq!(y.shape, x.shape);
        // every output value must exist somewhere in the input sample
        let v = y.data[5];
        assert!(x.data[0..3 * 64].contains(&v));
    }

    #[test]
    fn zero_pad_crop_no_flip_possible_identity() {
        // with pad 0 only the flip varies; run until we get identity
        let x = Tensor::from_vec(&[1, 1, 1, 4], vec![1.0, 2.0, 3.0, 4.0]);
        let mut rng = Rng::new(3);
        let mut saw_id = false;
        let mut saw_flip = false;
        for _ in 0..20 {
            let y = random_crop_flip(&x, 0, &mut rng);
            if y.data == vec![1.0, 2.0, 3.0, 4.0] {
                saw_id = true;
            }
            if y.data == vec![4.0, 3.0, 2.0, 1.0] {
                saw_flip = true;
            }
        }
        assert!(saw_id && saw_flip);
    }

    #[test]
    fn mixup_is_convex_combination() {
        let mut rng = Rng::new(2);
        let x = Tensor::randn(&[6, 2, 4, 4], 1.0, &mut rng);
        let (y, perm, lam) = mixup(&x, 0.4, &mut rng);
        assert_eq!(perm.len(), 6);
        assert!((0.05..=0.95).contains(&lam));
        let sample = 32;
        for i in 0..6 {
            let j = perm[i];
            let want = lam * x.data[i * sample] + (1.0 - lam) * x.data[j * sample];
            assert!((y.data[i * sample] - want).abs() < 1e-6);
        }
    }
}
