//! Synthetic image-classification datasets (CIFAR10/100- and MNIST-like).
//!
//! Each class gets a smooth random prototype (mixture of low-frequency
//! sinusoids) plus per-sample structured noise and a random shift — enough
//! intra-class variation that models must actually generalize, while
//! remaining CPU-trainable. This exercises the identical code path the
//! paper's CIFAR/ImageNet experiments exercise (conv stacks, augmentation,
//! Boolean optimizer); DESIGN.md §5 documents the substitution.

use crate::tensor::Tensor;
use crate::util::Rng;

/// In-memory labelled image dataset (NCHW, values in [-1, 1]).
pub struct ImageDataset {
    pub images: Vec<f32>,
    pub labels: Vec<usize>,
    pub n: usize,
    pub c: usize,
    pub h: usize,
    pub w: usize,
    pub classes: usize,
}

impl ImageDataset {
    /// CIFAR-like: `classes` smooth prototypes, additive noise σ, ±2px
    /// shifts. Same seed ⇒ same dataset.
    pub fn cifar_like(
        n: usize,
        classes: usize,
        c: usize,
        hw: usize,
        noise: f32,
        seed: u64,
    ) -> Self {
        let mut rng = Rng::new(seed);
        // class prototypes: sum of random low-frequency waves per channel
        let mut protos = vec![0.0f32; classes * c * hw * hw];
        for cls in 0..classes {
            for ch in 0..c {
                let (fx, fy) = (rng.range(0.5, 2.5), rng.range(0.5, 2.5));
                let (px, py) = (rng.range(0.0, 6.28), rng.range(0.0, 6.28));
                let amp2 = rng.range(0.2, 0.8);
                let (gx, gy) = (rng.range(0.5, 3.0), rng.range(0.5, 3.0));
                // class-keyed component: guarantees prototype separation
                // even when the random waves happen to collide
                let key = (cls + 1) as f32 / classes as f32 * 3.0 + 0.5;
                for y in 0..hw {
                    for x in 0..hw {
                        let u = x as f32 / hw as f32 * 6.28;
                        let v = y as f32 / hw as f32 * 6.28;
                        let val = (fx * u + px).sin() * (fy * v + py).cos()
                            + amp2 * (gx * u + gy * v).sin()
                            + 0.5 * (key * (u + 0.7 * v) + ch as f32).sin();
                        protos[((cls * c + ch) * hw + y) * hw + x] = val * 0.5;
                    }
                }
            }
        }
        let mut images = vec![0.0f32; n * c * hw * hw];
        let mut labels = vec![0usize; n];
        for i in 0..n {
            let cls = rng.below(classes);
            labels[i] = cls;
            let (sx, sy) = (rng.below(5) as isize - 2, rng.below(5) as isize - 2);
            for ch in 0..c {
                for y in 0..hw {
                    for x in 0..hw {
                        let yy = (y as isize + sy).rem_euclid(hw as isize) as usize;
                        let xx = (x as isize + sx).rem_euclid(hw as isize) as usize;
                        let p = protos[((cls * c + ch) * hw + yy) * hw + xx];
                        images[((i * c + ch) * hw + y) * hw + x] =
                            (p + noise * rng.normal()).clamp(-1.0, 1.0);
                    }
                }
            }
        }
        ImageDataset { images, labels, n, c, h: hw, w: hw, classes }
    }

    /// MNIST-like: binary ±1 patterns from class prototype bit-templates
    /// with label-preserving bit flips — the MLP/AOT-artifact workload.
    pub fn mnist_like(n: usize, classes: usize, d: usize, flip_p: f32, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let protos: Vec<f32> = (0..classes * d).map(|_| rng.sign()).collect();
        let mut images = vec![0.0f32; n * d];
        let mut labels = vec![0usize; n];
        for i in 0..n {
            let cls = rng.below(classes);
            labels[i] = cls;
            for j in 0..d {
                let v = protos[cls * d + j];
                images[i * d + j] = if rng.bernoulli(flip_p) { -v } else { v };
            }
        }
        ImageDataset { images, labels, n, c: 1, h: 1, w: d, classes }
    }

    /// Split into (train, val) with `n_train` samples in train — the two
    /// halves share the same class prototypes (same underlying task).
    pub fn split(self, n_train: usize) -> (ImageDataset, ImageDataset) {
        assert!(n_train < self.n);
        let sample = self.c * self.h * self.w;
        let train = ImageDataset {
            images: self.images[..n_train * sample].to_vec(),
            labels: self.labels[..n_train].to_vec(),
            n: n_train,
            c: self.c,
            h: self.h,
            w: self.w,
            classes: self.classes,
        };
        let val = ImageDataset {
            images: self.images[n_train * sample..].to_vec(),
            labels: self.labels[n_train..].to_vec(),
            n: self.n - n_train,
            c: self.c,
            h: self.h,
            w: self.w,
            classes: self.classes,
        };
        (train, val)
    }

    /// Gather a batch by indices into an NCHW tensor + label vec.
    pub fn batch(&self, idx: &[usize]) -> (Tensor, Vec<usize>) {
        let sample = self.c * self.h * self.w;
        let mut out = vec![0.0f32; idx.len() * sample];
        let mut labels = Vec::with_capacity(idx.len());
        for (bi, &i) in idx.iter().enumerate() {
            debug_assert!(i < self.n);
            out[bi * sample..(bi + 1) * sample]
                .copy_from_slice(&self.images[i * sample..(i + 1) * sample]);
            labels.push(self.labels[i]);
        }
        (
            Tensor::from_vec(&[idx.len(), self.c, self.h, self.w], out),
            labels,
        )
    }

    /// Flat (batch, features) view for MLP workloads.
    pub fn batch_flat(&self, idx: &[usize]) -> (Tensor, Vec<usize>) {
        let (t, l) = self.batch(idx);
        let cols = self.c * self.h * self.w;
        (t.reshape(&[idx.len(), cols]), l)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_generation() {
        let a = ImageDataset::cifar_like(20, 4, 3, 8, 0.1, 7);
        let b = ImageDataset::cifar_like(20, 4, 3, 8, 0.1, 7);
        assert_eq!(a.images, b.images);
        assert_eq!(a.labels, b.labels);
        let c = ImageDataset::cifar_like(20, 4, 3, 8, 0.1, 8);
        assert_ne!(a.labels, c.labels);
    }

    #[test]
    fn values_in_range_and_all_classes_present() {
        let d = ImageDataset::cifar_like(200, 10, 3, 8, 0.2, 1);
        assert!(d.images.iter().all(|&v| (-1.0..=1.0).contains(&v)));
        for cls in 0..10 {
            assert!(d.labels.iter().any(|&l| l == cls), "class {cls} missing");
        }
    }

    #[test]
    fn mnist_like_is_pm1() {
        let d = ImageDataset::mnist_like(50, 10, 64, 0.1, 3);
        assert!(d.images.iter().all(|&v| v == 1.0 || v == -1.0));
    }

    #[test]
    fn batch_gather() {
        let d = ImageDataset::cifar_like(10, 2, 1, 4, 0.0, 2);
        let (t, l) = d.batch(&[3, 7]);
        assert_eq!(t.shape, vec![2, 1, 4, 4]);
        assert_eq!(l, vec![d.labels[3], d.labels[7]]);
        assert_eq!(&t.data[0..16], &d.images[3 * 16..4 * 16]);
    }
}
