//! Segmentation dataset (stands in for Cityscapes / PASCAL VOC —
//! DESIGN.md §5): scenes of textured background plus randomly placed
//! rectangles/discs of class-specific texture. Class frequencies are
//! long-tailed by construction, reproducing the imbalance that motivates
//! the paper's rare-class sampling ablation (Appendix D.3.3, Table 11/12).

use crate::tensor::Tensor;
use crate::util::Rng;

/// Images (NCHW, [-1,1]) with per-pixel labels (class ids; `background`=0).
pub struct SegDataset {
    pub images: Vec<f32>,
    pub labels: Vec<usize>,
    pub n: usize,
    pub c: usize,
    pub hw: usize,
    pub classes: usize,
}

impl SegDataset {
    /// `classes` ≥ 2. Object class k appears with probability ∝ tail^k —
    /// higher classes are progressively rarer (long tail).
    pub fn scenes(n: usize, classes: usize, c: usize, hw: usize, tail: f32, seed: u64) -> Self {
        assert!(classes >= 2);
        let mut rng = Rng::new(seed);
        // class texture parameters (freq pair per class per channel)
        let tex: Vec<(f32, f32, f32)> = (0..classes * c)
            .map(|_| (rng.range(1.0, 8.0), rng.range(1.0, 8.0), rng.range(-0.5, 0.5)))
            .collect();
        let mut images = vec![0.0f32; n * c * hw * hw];
        let mut labels = vec![0usize; n * hw * hw];
        let probs: Vec<f32> = (1..classes).map(|k| tail.powi(k as i32 - 1)).collect();
        for i in 0..n {
            // background texture (class 0)
            for ch in 0..c {
                let (fx, fy, off) = tex[ch];
                for y in 0..hw {
                    for x in 0..hw {
                        let u = x as f32 / hw as f32;
                        let v = y as f32 / hw as f32;
                        images[((i * c + ch) * hw + y) * hw + x] =
                            (0.4 * (6.28 * (fx * u + fy * v)).sin() + off
                                + 0.1 * rng.normal())
                            .clamp(-1.0, 1.0);
                    }
                }
            }
            // 1–4 objects
            let nobj = 1 + rng.below(4);
            for _ in 0..nobj {
                // sample class by the long-tailed distribution
                let total: f32 = probs.iter().sum();
                let mut t = rng.uniform() * total;
                let mut cls = 1;
                for (k, &p) in probs.iter().enumerate() {
                    if t < p {
                        cls = k + 1;
                        break;
                    }
                    t -= p;
                }
                let size = 3 + rng.below(hw / 2);
                let cy = rng.below(hw);
                let cx = rng.below(hw);
                let disc = rng.bernoulli(0.5);
                for y in 0..hw {
                    for x in 0..hw {
                        let inside = if disc {
                            let dy = y as isize - cy as isize;
                            let dx = x as isize - cx as isize;
                            (dy * dy + dx * dx) as usize <= (size / 2) * (size / 2)
                        } else {
                            y >= cy.saturating_sub(size / 2)
                                && y < (cy + size / 2).min(hw)
                                && x >= cx.saturating_sub(size / 2)
                                && x < (cx + size / 2).min(hw)
                        };
                        if inside {
                            labels[(i * hw + y) * hw + x] = cls;
                            for ch in 0..c {
                                let (fx, fy, off) = tex[cls * c + ch];
                                let u = x as f32 / hw as f32;
                                let v = y as f32 / hw as f32;
                                images[((i * c + ch) * hw + y) * hw + x] =
                                    (0.6 * (6.28 * (fx * u + fy * v)).cos() + off
                                        + 0.1 * rng.normal())
                                    .clamp(-1.0, 1.0);
                            }
                        }
                    }
                }
            }
        }
        SegDataset { images, labels, n, c, hw, classes }
    }

    pub fn batch(&self, idx: &[usize]) -> (Tensor, Vec<usize>) {
        let sample = self.c * self.hw * self.hw;
        let lsample = self.hw * self.hw;
        let mut out = vec![0.0f32; idx.len() * sample];
        let mut labels = Vec::with_capacity(idx.len() * lsample);
        for (bi, &i) in idx.iter().enumerate() {
            out[bi * sample..(bi + 1) * sample]
                .copy_from_slice(&self.images[i * sample..(i + 1) * sample]);
            labels.extend_from_slice(&self.labels[i * lsample..(i + 1) * lsample]);
        }
        (
            Tensor::from_vec(&[idx.len(), self.c, self.hw, self.hw], out),
            labels,
        )
    }

    /// Per-image class labels (for the RCS sampler): dominant object class.
    pub fn dominant_class(&self) -> Vec<usize> {
        let lsample = self.hw * self.hw;
        (0..self.n)
            .map(|i| {
                let mut counts = vec![0usize; self.classes];
                for &l in &self.labels[i * lsample..(i + 1) * lsample] {
                    counts[l] += 1;
                }
                counts[0] = 0; // ignore background for dominance
                counts
                    .iter()
                    .enumerate()
                    .max_by_key(|(_, &c)| c)
                    .map(|(k, _)| k)
                    .unwrap_or(0)
            })
            .collect()
    }

    /// Class pixel frequencies (for the Table 11-style report).
    pub fn class_frequencies(&self) -> Vec<f32> {
        let mut counts = vec![0usize; self.classes];
        for &l in &self.labels {
            counts[l] += 1;
        }
        let total = self.labels.len() as f32;
        counts.iter().map(|&c| c as f32 / total).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn long_tailed_frequencies() {
        let d = SegDataset::scenes(40, 6, 3, 16, 0.5, 1);
        let f = d.class_frequencies();
        assert!(f[0] > 0.3, "background dominates: {f:?}");
        // later object classes are rarer than class 1
        assert!(f[1] > f[4], "tail should decay: {f:?}");
    }

    #[test]
    fn labels_in_range_and_batch_shapes() {
        let d = SegDataset::scenes(8, 4, 3, 16, 0.6, 2);
        assert!(d.labels.iter().all(|&l| l < 4));
        let (x, y) = d.batch(&[0, 3]);
        assert_eq!(x.shape, vec![2, 3, 16, 16]);
        assert_eq!(y.len(), 2 * 16 * 16);
    }

    #[test]
    fn deterministic() {
        let a = SegDataset::scenes(5, 4, 1, 8, 0.5, 3);
        let b = SegDataset::scenes(5, 4, 1, 8, 0.5, 3);
        assert_eq!(a.labels, b.labels);
    }
}
