//! GLUE-like synthetic NLU tasks (stands in for the GLUE benchmark —
//! DESIGN.md §5). Each task is a sequence-classification problem over a
//! small vocabulary with the discriminative structure of its GLUE
//! namesake: presence/absence (COLA-like acceptability), sentence-pair
//! agreement (MRPC/QQP-like), majority sentiment tokens (SST-2-like),
//! order sensitivity (RTE-like entailment).

use crate::util::Rng;

/// The synthetic GLUE-like task family (Table 7 columns).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GlueLikeTask {
    /// SST-2-like: label = majority of positive vs negative token groups.
    Sentiment,
    /// COLA-like: label = whether a required "grammar" token pair appears
    /// in order.
    Acceptability,
    /// MRPC/QQP-like: two halves; label = whether they share > half tokens.
    Paraphrase,
    /// RTE-like: label = whether the second half is a subset of the first.
    Entailment,
}

impl GlueLikeTask {
    pub fn all() -> [GlueLikeTask; 4] {
        [
            GlueLikeTask::Sentiment,
            GlueLikeTask::Acceptability,
            GlueLikeTask::Paraphrase,
            GlueLikeTask::Entailment,
        ]
    }

    pub fn name(&self) -> &'static str {
        match self {
            GlueLikeTask::Sentiment => "SST2-like",
            GlueLikeTask::Acceptability => "COLA-like",
            GlueLikeTask::Paraphrase => "MRPC-like",
            GlueLikeTask::Entailment => "RTE-like",
        }
    }
}

/// Token-sequence dataset: flat tokens (n × len), binary labels.
pub struct NlpDataset {
    pub tokens: Vec<usize>,
    pub labels: Vec<usize>,
    pub n: usize,
    pub len: usize,
    pub vocab: usize,
}

impl NlpDataset {
    pub fn generate(task: GlueLikeTask, n: usize, len: usize, vocab: usize, seed: u64) -> Self {
        assert!(vocab >= 8 && len >= 6);
        let mut rng = Rng::new(seed);
        let mut tokens = Vec::with_capacity(n * len);
        let mut labels = Vec::with_capacity(n);
        // token groups: [2, vocab/2) "positive", [vocab/2, vocab) "negative"
        let half = vocab / 2;
        for _ in 0..n {
            let label = rng.bernoulli(0.5) as usize;
            let mut seq: Vec<usize>;
            match task {
                GlueLikeTask::Sentiment => {
                    // majority of pos/neg tokens decides the label
                    let npos = if label == 1 { len / 2 + 1 + rng.below(len / 4) } else { rng.below(len / 2) };
                    seq = (0..len)
                        .map(|i| {
                            if i < npos {
                                2 + rng.below(half - 2)
                            } else {
                                half + rng.below(vocab - half)
                            }
                        })
                        .collect();
                    rng.shuffle(&mut seq);
                }
                GlueLikeTask::Acceptability => {
                    // "grammatical" iff token 2 appears before token 3
                    seq = (0..len).map(|_| 4 + rng.below(vocab - 4)).collect();
                    let a = rng.below(len / 2);
                    let b = len / 2 + rng.below(len / 2);
                    if label == 1 {
                        seq[a] = 2;
                        seq[b] = 3;
                    } else {
                        seq[a] = 3;
                        seq[b] = 2;
                    }
                }
                GlueLikeTask::Paraphrase => {
                    let h = len / 2;
                    let first: Vec<usize> = (0..h).map(|_| 2 + rng.below(vocab - 2)).collect();
                    let second: Vec<usize> = if label == 1 {
                        // copy with light noise
                        first
                            .iter()
                            .map(|&t| if rng.bernoulli(0.2) { 2 + rng.below(vocab - 2) } else { t })
                            .collect()
                    } else {
                        (0..h).map(|_| 2 + rng.below(vocab - 2)).collect()
                    };
                    seq = first.into_iter().chain(second).collect();
                }
                GlueLikeTask::Entailment => {
                    let h = len / 2;
                    let premise: Vec<usize> = (0..h).map(|_| 2 + rng.below(vocab - 2)).collect();
                    let hypothesis: Vec<usize> = if label == 1 {
                        (0..h).map(|_| premise[rng.below(h)]).collect()
                    } else {
                        (0..h).map(|_| 2 + rng.below(vocab - 2)).collect()
                    };
                    seq = premise.into_iter().chain(hypothesis).collect();
                }
            }
            debug_assert_eq!(seq.len(), len);
            tokens.extend(seq);
            labels.push(label);
        }
        NlpDataset { tokens, labels, n, len, vocab }
    }

    pub fn batch(&self, idx: &[usize]) -> (Vec<usize>, Vec<usize>) {
        let mut toks = Vec::with_capacity(idx.len() * self.len);
        let mut labels = Vec::with_capacity(idx.len());
        for &i in idx {
            toks.extend_from_slice(&self.tokens[i * self.len..(i + 1) * self.len]);
            labels.push(self.labels[i]);
        }
        (toks, labels)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_tasks_generate_balanced_labels() {
        for task in GlueLikeTask::all() {
            let d = NlpDataset::generate(task, 400, 12, 32, 1);
            let pos: usize = d.labels.iter().sum();
            assert!(pos > 120 && pos < 280, "{:?}: {pos}", task);
            assert!(d.tokens.iter().all(|&t| t < 32));
        }
    }

    #[test]
    fn acceptability_encodes_order() {
        let d = NlpDataset::generate(GlueLikeTask::Acceptability, 100, 10, 16, 2);
        for i in 0..100 {
            let seq = &d.tokens[i * 10..(i + 1) * 10];
            let pa = seq.iter().position(|&t| t == 2);
            let pb = seq.iter().position(|&t| t == 3);
            if let (Some(a), Some(b)) = (pa, pb) {
                assert_eq!(d.labels[i], usize::from(a < b), "seq {seq:?}");
            }
        }
    }

    #[test]
    fn deterministic() {
        let a = NlpDataset::generate(GlueLikeTask::Paraphrase, 50, 12, 24, 7);
        let b = NlpDataset::generate(GlueLikeTask::Paraphrase, 50, 12, 24, 7);
        assert_eq!(a.tokens, b.tokens);
        assert_eq!(a.labels, b.labels);
    }
}
