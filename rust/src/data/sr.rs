//! Super-resolution dataset (stands in for DIV2K/Set5/... — DESIGN.md §5):
//! procedural multi-frequency textures as HR ground truth, box-downsampled
//! LR inputs. Patch-based training exactly like the paper's EDSR setup
//! (Appendix D.2).

use crate::tensor::Tensor;
use crate::util::Rng;

/// Paired LR/HR patches, values in [0, 1], NCHW.
pub struct SrDataset {
    pub lr: Vec<f32>,
    pub hr: Vec<f32>,
    pub n: usize,
    pub c: usize,
    pub lr_hw: usize,
    pub scale: usize,
}

impl SrDataset {
    /// Generate `n` texture patches; HR is `lr_hw·scale` square.
    pub fn textures(n: usize, c: usize, lr_hw: usize, scale: usize, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let hr_hw = lr_hw * scale;
        let mut hr = vec![0.0f32; n * c * hr_hw * hr_hw];
        for i in 0..n {
            // random texture: 4 sinusoid components + a soft edge
            let comps: Vec<(f32, f32, f32, f32)> = (0..4)
                .map(|_| {
                    (
                        rng.range(0.5, 6.0),
                        rng.range(0.5, 6.0),
                        rng.range(0.0, 6.28),
                        rng.range(0.15, 0.5),
                    )
                })
                .collect();
            let edge = rng.range(0.2, 0.8);
            for ch in 0..c {
                let chs = 1.0 + 0.3 * ch as f32;
                for y in 0..hr_hw {
                    for x in 0..hr_hw {
                        let u = x as f32 / hr_hw as f32;
                        let v = y as f32 / hr_hw as f32;
                        let mut val = 0.5;
                        for &(fx, fy, ph, amp) in &comps {
                            val += amp * 0.4 * (6.28 * (fx * u * chs + fy * v) + ph).sin();
                        }
                        if u > edge {
                            val += 0.15; // sharp vertical edge: SR-relevant detail
                        }
                        hr[((i * c + ch) * hr_hw + y) * hr_hw + x] = val.clamp(0.0, 1.0);
                    }
                }
            }
        }
        // LR = scale×scale box filter (bicubic-like low-pass, simplified)
        let mut lr = vec![0.0f32; n * c * lr_hw * lr_hw];
        let inv = 1.0 / (scale * scale) as f32;
        for i in 0..n {
            for ch in 0..c {
                for y in 0..lr_hw {
                    for x in 0..lr_hw {
                        let mut s = 0.0;
                        for dy in 0..scale {
                            for dx in 0..scale {
                                s += hr[((i * c + ch) * hr_hw + y * scale + dy) * hr_hw
                                    + x * scale
                                    + dx];
                            }
                        }
                        lr[((i * c + ch) * lr_hw + y) * lr_hw + x] = s * inv;
                    }
                }
            }
        }
        SrDataset { lr, hr, n, c, lr_hw, scale }
    }

    pub fn batch(&self, idx: &[usize]) -> (Tensor, Tensor) {
        let ls = self.c * self.lr_hw * self.lr_hw;
        let hr_hw = self.lr_hw * self.scale;
        let hs = self.c * hr_hw * hr_hw;
        let mut lr = vec![0.0f32; idx.len() * ls];
        let mut hr = vec![0.0f32; idx.len() * hs];
        for (bi, &i) in idx.iter().enumerate() {
            lr[bi * ls..(bi + 1) * ls].copy_from_slice(&self.lr[i * ls..(i + 1) * ls]);
            hr[bi * hs..(bi + 1) * hs].copy_from_slice(&self.hr[i * hs..(i + 1) * hs]);
        }
        (
            Tensor::from_vec(&[idx.len(), self.c, self.lr_hw, self.lr_hw], lr),
            Tensor::from_vec(&[idx.len(), self.c, hr_hw, hr_hw], hr),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_and_range() {
        let d = SrDataset::textures(4, 3, 8, 2, 1);
        assert_eq!(d.hr.len(), 4 * 3 * 16 * 16);
        assert_eq!(d.lr.len(), 4 * 3 * 8 * 8);
        assert!(d.hr.iter().all(|&v| (0.0..=1.0).contains(&v)));
    }

    #[test]
    fn lr_is_box_mean_of_hr() {
        let d = SrDataset::textures(1, 1, 4, 2, 2);
        let want: f32 = (d.hr[0] + d.hr[1] + d.hr[8] + d.hr[9]) / 4.0;
        assert!((d.lr[0] - want).abs() < 1e-6);
    }

    #[test]
    fn deterministic() {
        let a = SrDataset::textures(2, 3, 8, 3, 9);
        let b = SrDataset::textures(2, 3, 8, 3, 9);
        assert_eq!(a.hr, b.hr);
    }
}
