//! Batch sampling: shuffled epochs and rare-class sampling (RCS) — the
//! paper's Appendix D.3.3, Eqs. (48)–(49): classes with low occurrence
//! frequency f_c are oversampled with probability
//! p_c ∝ exp((1 − f_c)/T).

use crate::util::Rng;

/// Epoch-based shuffled batch iterator, optionally with RCS.
pub struct BatchSampler {
    order: Vec<usize>,
    batch: usize,
    cursor: usize,
    rng: Rng,
    /// RCS: per-sample weights (unnormalized); `None` = uniform shuffle.
    weights: Option<Vec<f32>>,
    n: usize,
}

impl BatchSampler {
    pub fn new(n: usize, batch: usize, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let mut order: Vec<usize> = (0..n).collect();
        rng.shuffle(&mut order);
        BatchSampler { order, batch, cursor: 0, rng, weights: None, n }
    }

    /// Enable rare-class sampling from per-sample class labels.
    pub fn with_rcs(mut self, labels: &[usize], classes: usize, temperature: f32) -> Self {
        let p_c = rcs_probabilities(labels, classes, temperature);
        self.weights = Some(labels.iter().map(|&l| p_c[l]).collect());
        self
    }

    /// Next batch of indices (wraps across epochs, reshuffling).
    pub fn next_batch(&mut self) -> Vec<usize> {
        if let Some(w) = &self.weights {
            // weighted sampling with replacement (RCS semantics)
            let total: f32 = w.iter().sum();
            (0..self.batch)
                .map(|_| {
                    let mut t = self.rng.uniform() * total;
                    for (i, &wi) in w.iter().enumerate() {
                        if t < wi {
                            return i;
                        }
                        t -= wi;
                    }
                    w.len() - 1
                })
                .collect()
        } else {
            let mut out = Vec::with_capacity(self.batch);
            for _ in 0..self.batch {
                if self.cursor >= self.order.len() {
                    self.rng.shuffle(&mut self.order);
                    self.cursor = 0;
                }
                out.push(self.order[self.cursor]);
                self.cursor += 1;
            }
            out
        }
    }

    pub fn len(&self) -> usize {
        self.n
    }

    pub fn is_empty(&self) -> bool {
        self.n == 0
    }
}

/// Eq. (48)–(49): class sampling probabilities from occurrence frequency.
/// `labels` may contain ids ≥ `classes` (e.g. an ignore label); they get
/// probability 0.
pub fn rcs_probabilities(labels: &[usize], classes: usize, temperature: f32) -> Vec<f32> {
    let mut counts = vec![0usize; classes];
    let mut total = 0usize;
    for &l in labels {
        if l < classes {
            counts[l] += 1;
            total += 1;
        }
    }
    let f: Vec<f32> = counts
        .iter()
        .map(|&c| if total == 0 { 0.0 } else { c as f32 / total as f32 })
        .collect();
    let e: Vec<f32> = f.iter().map(|&fc| ((1.0 - fc) / temperature).exp()).collect();
    let z: f32 = e.iter().sum();
    e.iter().map(|&v| v / z).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_sampler_covers_epoch() {
        let mut s = BatchSampler::new(10, 5, 1);
        let mut seen: Vec<usize> = s.next_batch();
        seen.extend(s.next_batch());
        seen.sort_unstable();
        assert_eq!(seen, (0..10).collect::<Vec<_>>(), "one epoch covers all");
    }

    #[test]
    fn rcs_prefers_rare_classes() {
        // class 0 frequent, class 1 rare
        let labels: Vec<usize> = (0..100).map(|i| usize::from(i >= 95)).collect();
        let p = rcs_probabilities(&labels, 2, 0.5);
        assert!(p[1] > p[0], "rare class must be upsampled: {p:?}");
        assert!((p.iter().sum::<f32>() - 1.0).abs() < 1e-5);
        // and the sampler actually draws it more often than its frequency
        let mut s = BatchSampler::new(100, 50, 2).with_rcs(&labels, 2, 0.5);
        let mut rare = 0;
        for _ in 0..20 {
            for i in s.next_batch() {
                if labels[i] == 1 {
                    rare += 1;
                }
            }
        }
        let frac = rare as f32 / 1000.0;
        assert!(frac > 0.15, "rare fraction {frac} should beat base rate 0.05");
    }

    #[test]
    fn rcs_temperature_sharpens() {
        let labels: Vec<usize> = (0..100).map(|i| usize::from(i >= 90)).collect();
        let cold = rcs_probabilities(&labels, 2, 0.1);
        let warm = rcs_probabilities(&labels, 2, 10.0);
        assert!(cold[1] > warm[1], "lower T → sharper preference");
    }
}
