//! Data pipeline: deterministic synthetic datasets standing in for the
//! paper's benchmarks (substitution table in DESIGN.md §5), batch
//! sampling with shuffling and rare-class sampling (RCS, Appendix D.3.3
//! Eqs. 48–49), and the augmentations of Appendix D.1.1 (flip, crop,
//! mixup).
//!
//! Every generator takes an explicit seed: the same config always yields
//! the same dataset, so experiments are reproducible bit-for-bit.

mod augment;
mod nlp;
mod sampler;
mod seg;
mod sr;
mod synth;

pub use augment::{mixup, random_crop_flip};
pub use nlp::{GlueLikeTask, NlpDataset};
pub use sampler::{rcs_probabilities, BatchSampler};
pub use seg::SegDataset;
pub use sr::SrDataset;
pub use synth::ImageDataset;
