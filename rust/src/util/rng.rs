//! xoshiro256** PRNG with SplitMix64 seeding (Blackman & Vigna).

/// Deterministic, cloneable PRNG used everywhere randomness is needed.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via SplitMix64 so that nearby seeds give unrelated streams.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f32 {
        ((self.next_u64() >> 40) as f32) * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn range(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f32 {
        let u1 = self.uniform().max(1e-12);
        let u2 = self.uniform();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f32::consts::PI * u2).cos()
    }

    /// Random sign in {-1.0, +1.0}.
    #[inline]
    pub fn sign(&mut self) -> f32 {
        if self.next_u64() & 1 == 0 { 1.0 } else { -1.0 }
    }

    /// Random bool with probability `p` of `true`.
    #[inline]
    pub fn bernoulli(&mut self, p: f32) -> bool {
        self.uniform() < p
    }

    /// Fork an independent stream (for per-worker RNGs).
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::new(self.next_u64() ^ stream.wrapping_mul(0xA076_1D64_78BD_642F))
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_seed_sensitive() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        let mut c = Rng::new(43);
        let va: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let vb: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        let vc: Vec<u64> = (0..8).map(|_| c.next_u64()).collect();
        assert_eq!(va, vb);
        assert_ne!(va, vc);
    }

    #[test]
    fn uniform_in_unit_interval_and_roughly_centered() {
        let mut r = Rng::new(7);
        let n = 20_000;
        let mean: f32 = (0..n).map(|_| r.uniform()).sum::<f32>() / n as f32;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 40_000;
        let xs: Vec<f32> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f32>() / n as f32;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / n as f32;
        assert!(mean.abs() < 0.03, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn below_is_in_range() {
        let mut r = Rng::new(3);
        for _ in 0..1000 {
            assert!(r.below(17) < 17);
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort_unstable();
        assert_eq!(s, (0..100).collect::<Vec<_>>());
    }
}
