//! CRC-32 (IEEE 802.3, reflected polynomial 0xEDB88320), table-driven and
//! dependency-free.
//!
//! One implementation shared by the checkpoint format (per-record integrity
//! trailer, DESIGN.md §Training-system) and the distributed-training wire
//! protocol (per-frame checksum, DESIGN.md §Distributed-Training). The
//! variant matches zlib's `crc32()` so externally produced checksums can be
//! cross-checked with any standard tool.

/// 256-entry lookup table for the reflected IEEE polynomial, built at
/// compile time so the hot path is a single table index per byte.
const TABLE: [u32; 256] = build_table();

const fn build_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
}

/// Incremental CRC-32 state. `Crc32::new().update(a).update(b).finish()`
/// equals [`crc32`] over the concatenation of `a` and `b`.
#[derive(Debug, Clone, Copy)]
pub struct Crc32 {
    state: u32,
}

impl Default for Crc32 {
    fn default() -> Self {
        Self::new()
    }
}

impl Crc32 {
    pub fn new() -> Self {
        Crc32 { state: 0xFFFF_FFFF }
    }

    /// Fold `bytes` into the running checksum.
    pub fn update(mut self, bytes: &[u8]) -> Self {
        for &b in bytes {
            self.state = TABLE[((self.state ^ b as u32) & 0xFF) as usize] ^ (self.state >> 8);
        }
        self
    }

    /// Final checksum value.
    pub fn finish(self) -> u32 {
        self.state ^ 0xFFFF_FFFF
    }
}

/// One-shot CRC-32 of `bytes`.
pub fn crc32(bytes: &[u8]) -> u32 {
    Crc32::new().update(bytes).finish()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_vectors() {
        // Reference values from zlib / RFC 3720 appendix examples.
        assert_eq!(crc32(b""), 0x0000_0000);
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b"The quick brown fox jumps over the lazy dog"), 0x414F_A339);
        assert_eq!(crc32(&[0u8; 32]), 0x190A_55AD);
        assert_eq!(crc32(&[0xFFu8; 32]), 0xFF6C_AB0B);
    }

    #[test]
    fn incremental_equals_one_shot() {
        let data: Vec<u8> = (0..=255u8).cycle().take(1000).collect();
        let whole = crc32(&data);
        for split in [0, 1, 7, 499, 999, 1000] {
            let (a, b) = data.split_at(split);
            assert_eq!(Crc32::new().update(a).update(b).finish(), whole);
        }
    }

    #[test]
    fn detects_single_bit_flips() {
        let data = b"bold checkpoint record payload".to_vec();
        let base = crc32(&data);
        for i in 0..data.len() {
            for bit in 0..8 {
                let mut corrupt = data.clone();
                corrupt[i] ^= 1 << bit;
                assert_ne!(crc32(&corrupt), base, "flip at byte {i} bit {bit} undetected");
            }
        }
    }
}
