//! Persistent intra-op worker pool (DESIGN.md §Parallelism).
//!
//! Every hot kernel in the crate — the packed [`crate::tensor::BitMatrix`]
//! kernels, the dense [`crate::tensor::Tensor`] GEMMs, `im2col`/`col2im`
//! and the word-parallel [`crate::optim::BooleanOptimizer`] step — shards
//! its *output rows* across this pool instead of spawning OS threads per
//! call. The pool is:
//!
//! * **zero-dependency**: `std` threads, a `Mutex<VecDeque>` injector and
//!   two condvars — no rayon/crossbeam (the offline registry has neither);
//! * **lazy and global**: the first parallel kernel call spawns
//!   `num_threads() − 1` workers (the submitting thread is the last
//!   "worker": it helps drain the queue, so a pool of size 1 degenerates
//!   to plain sequential execution and tiny kernels never pay a handoff);
//! * **persistent**: workers park on a condvar between jobs and are
//!   reused for the life of the process — the per-call cost is one
//!   enqueue + wakeup (~µs), not a `thread::spawn`/join pair (~100 µs);
//! * **deterministic by construction**: the scoped helpers only hand out
//!   *disjoint output-row ranges*, and every kernel runs the same
//!   per-element arithmetic in the same order within a row as its
//!   sequential form — so results are bit-exact for any thread count
//!   (asserted in `rust/tests/parallel_determinism.rs`).
//!
//! # Sizing and composition
//!
//! `BOLD_NUM_THREADS` caps the global pool (default:
//! `available_parallelism`). Outer coarse-grained parallelism — the
//! data-parallel replicas of `coordinator::ParallelTrainer`, the batch
//! workers of `runtime::serve` — *composes* with intra-op sharding through
//! a thread-local **budget**: the outer layer wraps each of its workers in
//! a [`BudgetGuard`] carving out `num_threads() / n_workers` lanes, and
//! every kernel consults [`thread_budget`] when deciding its shard count.
//! The pool itself stays fixed-size, so even a mis-set budget can only
//! queue more tasks, never oversubscribe the machine with OS threads.

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex, OnceLock};

/// A lifetime-erased scoped task (see safety argument in [`run_scoped`]).
type Task = Box<dyn FnOnce() + Send + 'static>;

struct Queue {
    jobs: Mutex<VecDeque<Task>>,
    /// Signalled when a job is pushed; workers park here when idle.
    available: Condvar,
}

struct Pool {
    queue: &'static Queue,
    /// Spawned worker threads (`num_threads() − 1`; 0 on a 1-core budget).
    workers: usize,
}

/// Global pool handle, spawned on first parallel kernel call.
static POOL: OnceLock<Pool> = OnceLock::new();

/// Pool size: `BOLD_NUM_THREADS` if set (≥ 1), else the machine's
/// available parallelism. Read once; changing the env var mid-process has
/// no effect.
pub fn num_threads() -> usize {
    static N: OnceLock<usize> = OnceLock::new();
    *N.get_or_init(|| {
        std::env::var("BOLD_NUM_THREADS")
            .ok()
            .and_then(|s| s.trim().parse::<usize>().ok())
            .filter(|&n| n >= 1)
            .unwrap_or_else(|| {
                std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
            })
    })
}

/// Fair intra-op thread budget for each of `n_children` child PROCESSES
/// sharing this machine (e.g. `bold train-dist --spawn N` workers): the
/// process-level face of the [`BudgetGuard`] composition rule. Children
/// receive it via `BOLD_NUM_THREADS`, since a child's pool cannot consult
/// the parent's thread-local budget.
pub fn child_budget(n_children: usize) -> usize {
    (num_threads() / n_children.max(1)).max(1)
}

fn pool() -> &'static Pool {
    POOL.get_or_init(|| {
        let queue: &'static Queue = Box::leak(Box::new(Queue {
            jobs: Mutex::new(VecDeque::new()),
            available: Condvar::new(),
        }));
        let workers = num_threads().saturating_sub(1);
        for i in 0..workers {
            std::thread::Builder::new()
                .name(format!("bold-pool-{i}"))
                .spawn(move || worker_loop(queue))
                .expect("spawn pool worker");
        }
        Pool { queue, workers }
    })
}

fn worker_loop(queue: &'static Queue) {
    loop {
        let job = {
            let mut q = queue.jobs.lock().unwrap();
            loop {
                if let Some(job) = q.pop_front() {
                    break job;
                }
                q = queue.available.wait(q).unwrap();
            }
        };
        job();
    }
}

fn try_pop(queue: &Queue) -> Option<Task> {
    queue.jobs.lock().unwrap().pop_front()
}

// ---------------------------------------------------------------------------
// thread budget (outer-parallelism handoff)
// ---------------------------------------------------------------------------

thread_local! {
    static BUDGET: std::cell::Cell<Option<usize>> = const { std::cell::Cell::new(None) };
}

/// Intra-op threads the *current thread's* kernels may shard across:
/// the innermost active [`BudgetGuard`], else the full pool size.
pub fn thread_budget() -> usize {
    BUDGET.with(|b| b.get()).unwrap_or_else(num_threads)
}

/// RAII handoff of intra-op parallelism to an outer parallel layer: while
/// the guard lives, kernels called **on this thread** shard across at most
/// `n` lanes. `ParallelTrainer` gives each data-parallel replica
/// `num_threads() / workers`; the serve workers do the same — so
/// outer × inner never exceeds the pool size by design.
pub struct BudgetGuard {
    prev: Option<usize>,
}

impl BudgetGuard {
    pub fn new(n: usize) -> Self {
        let prev = BUDGET.with(|b| b.replace(Some(n.max(1))));
        BudgetGuard { prev }
    }
}

impl Drop for BudgetGuard {
    fn drop(&mut self) {
        let prev = self.prev;
        BUDGET.with(|b| b.set(prev));
    }
}

/// Run `f` under a temporary thread budget (test/bench helper: the
/// determinism suite runs every kernel with budget 1 vs N and asserts
/// bit-exact equality).
pub fn with_thread_budget<R>(n: usize, f: impl FnOnce() -> R) -> R {
    let _guard = BudgetGuard::new(n);
    f()
}

/// Minimum f32 multiply-adds per pool shard — the shared work quantum for
/// the dense GEMMs and the LUT-based packed backward kernels (~130 Ki
/// MACs ≈ tens of µs, comfortably above the enqueue/wakeup overhead).
/// Kernel families with different per-element costs (packed word-ops,
/// copy/scatter moves) define their own quanta next to their kernels.
pub const MAC_QUANTUM: usize = 1 << 17;

/// Shard count for a kernel producing `rows` independent output rows with
/// `total_work` scalar operations overall: work-proportional (one shard
/// per `quantum` of work, so tiny kernels stay sequential), capped by the
/// current [`thread_budget`] and by `rows` (the shard unit).
pub fn shards_for(total_work: usize, rows: usize, quantum: usize) -> usize {
    let by_work = total_work / quantum.max(1);
    if by_work <= 1 {
        return 1;
    }
    by_work.min(thread_budget()).min(rows).max(1)
}

// ---------------------------------------------------------------------------
// scoped execution
// ---------------------------------------------------------------------------

/// Completion latch: counts outstanding tasks, carries the first panic.
struct LatchState {
    remaining: usize,
    panic: Option<Box<dyn std::any::Any + Send>>,
}

struct Latch {
    state: Mutex<LatchState>,
    done: Condvar,
}

impl Latch {
    fn new(n: usize) -> Self {
        Latch {
            state: Mutex::new(LatchState { remaining: n, panic: None }),
            done: Condvar::new(),
        }
    }

    fn complete(&self, panic: Option<Box<dyn std::any::Any + Send>>) {
        let mut s = self.state.lock().unwrap();
        s.remaining -= 1;
        if s.panic.is_none() {
            s.panic = panic;
        }
        if s.remaining == 0 {
            self.done.notify_all();
        }
    }

    fn is_done(&self) -> bool {
        self.state.lock().unwrap().remaining == 0
    }

    fn wait(&self) {
        let mut s = self.state.lock().unwrap();
        while s.remaining > 0 {
            s = self.done.wait(s).unwrap();
        }
        if let Some(payload) = s.panic.take() {
            drop(s);
            std::panic::resume_unwind(payload);
        }
    }
}

/// Execute `tasks` to completion across the pool, the calling thread
/// included. Blocks until every task has finished; a panicking task is
/// re-raised on the caller after all siblings complete.
///
/// Tasks may borrow from the caller's stack (the closures are **not**
/// `'static`): this is sound because `run_scoped` does not return until
/// every task has run, so no borrow outlives its owner — the same
/// contract as `std::thread::scope`, on persistent threads. The one
/// `unsafe` block below erases the closure lifetime to hand the task to a
/// `'static` worker; the latch wait is what discharges it.
///
/// Deadlock-freedom under nesting (a pool task calling `run_scoped`
/// again): the caller *helps* — it drains the shared queue until its own
/// latch clears or the queue is empty before parking, so every one of its
/// tasks is either executed by the caller itself or already claimed by a
/// running worker (which always makes progress).
pub fn run_scoped<F: FnOnce() + Send>(mut tasks: Vec<F>) {
    match tasks.len() {
        0 => return,
        1 => return (tasks.pop().unwrap())(),
        _ => {}
    }
    let pool = pool();
    if pool.workers == 0 {
        for t in tasks {
            t();
        }
        return;
    }
    let latch = Latch::new(tasks.len());
    {
        let mut q = pool.queue.jobs.lock().unwrap();
        for t in tasks {
            let l: &Latch = &latch;
            let job: Box<dyn FnOnce() + Send + '_> = Box::new(move || {
                let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(t));
                l.complete(r.err());
            });
            // SAFETY: `job` borrows `latch` and whatever the caller's
            // tasks capture. `run_scoped` blocks on `latch.wait()` until
            // every job has completed, so all borrows outlive every use;
            // the 'static bound is a queue-plumbing fiction never relied
            // on for actual lifetime.
            let job: Task = unsafe {
                std::mem::transmute::<Box<dyn FnOnce() + Send + '_>, Task>(job)
            };
            q.push_back(job);
        }
    }
    pool.queue.available.notify_all();
    // Help: run queued tasks (ours or a sibling scope's — either way the
    // owning scope is still waiting, so its borrows are alive) until our
    // own latch clears or the queue drains, then wait for stragglers
    // claimed by other workers.
    while !latch.is_done() {
        match try_pop(pool.queue) {
            Some(job) => job(),
            None => break,
        }
    }
    latch.wait();
}

/// Split `data` into `shards` near-equal contiguous row chunks and run
/// `f(start_row, chunk)` for each on the pool; `shards <= 1` (or a
/// degenerate stride) runs `f(0, data)` inline. The chunks are disjoint
/// `&mut` ranges — the sharding primitive for kernels that chunk a single
/// output buffer (`backward_weight[_masked]`, `matmul_at`,
/// `im2col`/`col2im`); kernels that must co-chunk several buffers
/// (input rows zipped with output rows) hand-roll the same split over
/// [`run_scoped`] directly. `stride` is the number of elements per
/// logical row; chunk boundaries always fall on row boundaries.
pub fn for_each_row_chunk<T: Send, F>(data: &mut [T], stride: usize, shards: usize, f: F)
where
    F: Fn(usize, &mut [T]) + Sync,
{
    let rows = if stride == 0 { 0 } else { data.len() / stride };
    if shards <= 1 || rows <= 1 || data.is_empty() {
        f(0, data);
        return;
    }
    let rows_per = rows.div_ceil(shards.min(rows));
    let chunk_len = rows_per * stride;
    let fr = &f;
    let tasks: Vec<_> = data
        .chunks_mut(chunk_len)
        .enumerate()
        .map(|(ci, chunk)| move || fr(ci * rows_per, chunk))
        .collect();
    run_scoped(tasks);
}

// ---------------------------------------------------------------------------
// bounded hand-off queue (coarse-grained worker pools)
// ---------------------------------------------------------------------------

/// A bounded MPMC hand-off queue for *coarse-grained* worker pools — the
/// accepted-connection queue of the HTTP front-end
/// (`runtime::net`), structurally the same bounded-queue/condvar pattern
/// as the serve request queue. This is deliberately **not** the global
/// kernel pool above: consumers of a `JobQueue` block on I/O for long
/// stretches, which would starve the latency-critical kernel shards if
/// they shared threads; instead the owner spawns its own small set of
/// threads that pull from here.
///
/// Semantics:
/// * [`JobQueue::try_push`] never blocks — a full (or closed) queue hands
///   the item back, which is the *admission-control point*: the producer
///   sheds load explicitly (HTTP 503) instead of queueing unboundedly;
/// * [`JobQueue::pop`] blocks until an item arrives or the queue is
///   closed *and* drained — so closing performs a graceful drain: already
///   accepted items are still handed out, then every consumer wakes up
///   and sees `None`.
pub struct JobQueue<T> {
    state: Mutex<JobQueueState<T>>,
    available: Condvar,
    cap: usize,
}

struct JobQueueState<T> {
    items: VecDeque<T>,
    closed: bool,
}

impl<T> JobQueue<T> {
    pub fn bounded(cap: usize) -> Self {
        assert!(cap >= 1, "JobQueue needs capacity >= 1");
        JobQueue {
            state: Mutex::new(JobQueueState { items: VecDeque::with_capacity(cap), closed: false }),
            available: Condvar::new(),
            cap,
        }
    }

    /// Non-blocking enqueue; `Err(item)` when full or closed.
    pub fn try_push(&self, item: T) -> Result<(), T> {
        let mut s = self.state.lock().unwrap();
        if s.closed || s.items.len() >= self.cap {
            return Err(item);
        }
        s.items.push_back(item);
        drop(s);
        self.available.notify_one();
        Ok(())
    }

    /// Blocking dequeue; `None` once the queue is closed and drained.
    pub fn pop(&self) -> Option<T> {
        let mut s = self.state.lock().unwrap();
        loop {
            if let Some(item) = s.items.pop_front() {
                return Some(item);
            }
            if s.closed {
                return None;
            }
            s = self.available.wait(s).unwrap();
        }
    }

    /// Stop accepting new items and wake all blocked consumers; items
    /// already queued are still popped (drain-then-`None`).
    pub fn close(&self) {
        self.state.lock().unwrap().closed = true;
        self.available.notify_all();
    }

    pub fn len(&self) -> usize {
        self.state.lock().unwrap().items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn child_budget_splits_the_pool_fairly() {
        let n = num_threads();
        assert_eq!(child_budget(1), n.max(1));
        assert!(child_budget(2) >= 1);
        assert!(child_budget(2) <= n);
        // degenerate inputs never hand out a zero budget
        assert_eq!(child_budget(0), n.max(1));
        assert_eq!(child_budget(usize::MAX), 1);
    }

    #[test]
    fn run_scoped_executes_every_task_with_borrows() {
        let mut out = vec![0usize; 64];
        {
            let tasks: Vec<_> = out
                .chunks_mut(4)
                .enumerate()
                .map(|(i, chunk)| {
                    move || {
                        for (k, v) in chunk.iter_mut().enumerate() {
                            *v = i * 4 + k;
                        }
                    }
                })
                .collect();
            run_scoped(tasks);
        }
        assert_eq!(out, (0..64).collect::<Vec<_>>());
    }

    #[test]
    fn nested_run_scoped_does_not_deadlock() {
        let mut sums = vec![0u64; 8];
        let tasks: Vec<_> = sums
            .iter_mut()
            .enumerate()
            .map(|(i, s)| {
                move || {
                    let mut inner = vec![0u64; 4];
                    let sub: Vec<_> = inner
                        .iter_mut()
                        .enumerate()
                        .map(|(j, v)| move || *v = (i * 4 + j) as u64)
                        .collect();
                    run_scoped(sub);
                    *s = inner.iter().sum();
                }
            })
            .collect();
        run_scoped(tasks);
        let total: u64 = sums.iter().sum();
        assert_eq!(total, (0..32).sum::<u64>());
    }

    #[test]
    fn panic_in_task_propagates_after_siblings_finish() {
        let hits = std::sync::atomic::AtomicUsize::new(0);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let tasks: Vec<_> = (0..6)
                .map(|i| {
                    let hits = &hits;
                    move || {
                        if i == 3 {
                            panic!("shard boom");
                        }
                        hits.fetch_add(1, std::sync::atomic::Ordering::SeqCst);
                    }
                })
                .collect();
            run_scoped(tasks);
        }));
        assert!(result.is_err(), "panic must propagate to the caller");
        assert_eq!(hits.load(std::sync::atomic::Ordering::SeqCst), 5);
    }

    #[test]
    fn budget_guard_nests_and_restores() {
        let base = thread_budget();
        {
            let _a = BudgetGuard::new(3);
            assert_eq!(thread_budget(), 3);
            {
                let _b = BudgetGuard::new(1);
                assert_eq!(thread_budget(), 1);
            }
            assert_eq!(thread_budget(), 3);
        }
        assert_eq!(thread_budget(), base);
    }

    #[test]
    fn shards_scale_with_work_and_caps() {
        with_thread_budget(8, || {
            assert_eq!(shards_for(10, 100, 1 << 16), 1, "tiny work stays sequential");
            assert_eq!(shards_for(4 << 16, 100, 1 << 16), 4, "work-proportional");
            assert_eq!(shards_for(usize::MAX / 2, 3, 1 << 16), 3, "row-capped");
            assert_eq!(shards_for(usize::MAX / 2, 100, 1 << 16), 8, "budget-capped");
        });
        with_thread_budget(1, || {
            assert_eq!(shards_for(usize::MAX / 2, 100, 1 << 16), 1);
        });
    }

    #[test]
    fn job_queue_bounds_drains_and_closes() {
        let q = JobQueue::bounded(2);
        assert!(q.try_push(1).is_ok());
        assert!(q.try_push(2).is_ok());
        assert_eq!(q.try_push(3), Err(3), "full queue hands the item back");
        q.close();
        assert_eq!(q.try_push(4), Err(4), "closed queue rejects");
        assert_eq!(q.pop(), Some(1), "close still drains");
        assert_eq!(q.pop(), Some(2));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn job_queue_wakes_blocked_consumers_on_close() {
        let q = std::sync::Arc::new(JobQueue::<usize>::bounded(4));
        let handles: Vec<_> = (0..3)
            .map(|_| {
                let q = std::sync::Arc::clone(&q);
                std::thread::spawn(move || q.pop())
            })
            .collect();
        std::thread::sleep(std::time::Duration::from_millis(20));
        assert!(q.try_push(7).is_ok());
        q.close();
        let got: Vec<Option<usize>> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        assert_eq!(got.iter().filter(|g| g.is_some()).count(), 1);
        assert_eq!(got.iter().filter(|g| g.is_none()).count(), 2);
    }

    #[test]
    fn for_each_row_chunk_covers_all_rows() {
        with_thread_budget(4, || {
            for rows in [0usize, 1, 2, 3, 7, 8, 9] {
                let stride = 5;
                let mut data = vec![0u32; rows * stride];
                for_each_row_chunk(&mut data, stride, 4, |row0, chunk| {
                    for (r, row) in chunk.chunks_mut(stride).enumerate() {
                        for v in row.iter_mut() {
                            *v = (row0 + r + 1) as u32;
                        }
                    }
                });
                for r in 0..rows {
                    assert!(data[r * stride..(r + 1) * stride].iter().all(|&v| v == (r + 1) as u32),
                        "rows={rows} r={r}");
                }
            }
        });
    }
}
