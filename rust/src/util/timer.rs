//! Wall-clock timing helpers for the bench harness (no criterion offline).

use std::time::Instant;

/// Measure a closure's wall time in seconds.
pub fn time_it<F: FnMut()>(mut f: F) -> f64 {
    let t0 = Instant::now();
    f();
    t0.elapsed().as_secs_f64()
}

/// A simple named timer that reports median/min over repeated runs.
pub struct Timer {
    pub name: String,
    samples: Vec<f64>,
}

impl Timer {
    pub fn new(name: &str) -> Self {
        Timer { name: name.to_string(), samples: Vec::new() }
    }

    /// Run `f` `reps` times after `warmup` unrecorded runs.
    pub fn bench<F: FnMut()>(&mut self, warmup: usize, reps: usize, mut f: F) -> &mut Self {
        for _ in 0..warmup {
            f();
        }
        for _ in 0..reps {
            self.samples.push(time_it(&mut f));
        }
        self
    }

    pub fn median(&self) -> f64 {
        let mut s = self.samples.clone();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        if s.is_empty() { f64::NAN } else { s[s.len() / 2] }
    }

    pub fn min(&self) -> f64 {
        self.samples.iter().copied().fold(f64::INFINITY, f64::min)
    }

    /// Print a one-line report; `work` = logical ops per run for a rate.
    pub fn report(&self, work: Option<f64>) {
        let med = self.median();
        match work {
            Some(w) => println!(
                "{:<44} median {:>10.3} ms   min {:>10.3} ms   {:>8.2} Gop/s",
                self.name,
                med * 1e3,
                self.min() * 1e3,
                w / med / 1e9
            ),
            None => println!(
                "{:<44} median {:>10.3} ms   min {:>10.3} ms",
                self.name,
                med * 1e3,
                self.min() * 1e3
            ),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timer_collects_samples() {
        let mut t = Timer::new("noop");
        t.bench(1, 5, || {
            std::hint::black_box(1 + 1);
        });
        assert!(t.median() >= 0.0);
        assert!(t.min() <= t.median());
    }
}
