//! Zero-dependency SIGINT/SIGTERM shutdown flag.
//!
//! `bold serve-http` and `bold train-dist` are long-running foreground
//! processes; Ctrl-C under load must trigger the same graceful drain as
//! `POST /admin/shutdown` instead of tearing connections mid-response. The
//! offline registry has no `signal-hook` or `libc` crate, so on Unix we
//! declare the two C symbols we need (`signal`, `raise` — already linked
//! into every std binary) ourselves and install a handler that does the
//! only async-signal-safe thing possible: set a static [`AtomicBool`]. The
//! main loop polls [`triggered`] at its own cadence.
//!
//! Non-Unix targets compile to a no-op installer so the call sites stay
//! unconditional.

use std::sync::atomic::{AtomicBool, Ordering};

static TRIGGERED: AtomicBool = AtomicBool::new(false);

#[cfg(unix)]
mod imp {
    use super::*;

    pub const SIGINT: i32 = 2;
    pub const SIGTERM: i32 = 15;

    // `sighandler_t` is `void (*)(int)`; `signal(2)` and `raise(3)` are in
    // every libc that std itself links against, so no crate is needed.
    extern "C" {
        fn signal(signum: i32, handler: usize) -> usize;
        fn raise(signum: i32) -> i32;
    }

    extern "C" fn on_signal(_signum: i32) {
        // Only async-signal-safe operation: a relaxed atomic store. The
        // poller upgrades visibility with an Acquire load.
        TRIGGERED.store(true, Ordering::Release);
    }

    pub fn install() {
        unsafe {
            signal(SIGINT, on_signal as usize);
            signal(SIGTERM, on_signal as usize);
        }
    }

    /// Deliver `signum` to the current process (test hook).
    pub fn raise_signal(signum: i32) {
        unsafe {
            raise(signum);
        }
    }
}

#[cfg(not(unix))]
mod imp {
    pub const SIGINT: i32 = 2;
    pub const SIGTERM: i32 = 15;
    pub fn install() {}
    pub fn raise_signal(_signum: i32) {}
}

pub use imp::{SIGINT, SIGTERM};

/// Install the SIGINT/SIGTERM handler. Idempotent; call once at the top of
/// a long-running command before entering its poll loop.
pub fn install_shutdown_handler() {
    imp::install();
}

/// True once SIGINT or SIGTERM has been received (sticky).
pub fn triggered() -> bool {
    TRIGGERED.load(Ordering::Acquire)
}

/// Reset the flag (tests only — production commands exit after a trigger).
pub fn reset() {
    TRIGGERED.store(false, Ordering::Release);
}

/// Send `signum` to the current process. Exposed for the integration tests
/// that prove Ctrl-C drains gracefully without spawning a child process.
pub fn raise_for_test(signum: i32) {
    imp::raise_signal(signum);
}

#[cfg(all(test, unix))]
mod tests {
    use super::*;

    // Signal disposition is process-global state, so keep everything in
    // one test to avoid cross-test races under the parallel harness.
    #[test]
    fn handler_sets_sticky_flag_for_int_and_term() {
        install_shutdown_handler();
        reset();
        assert!(!triggered());

        raise_for_test(SIGTERM);
        assert!(triggered(), "SIGTERM must set the flag");
        // Sticky: repeated polls still see it.
        assert!(triggered());

        reset();
        raise_for_test(SIGINT);
        assert!(triggered(), "SIGINT must set the flag");
        reset();
    }
}
