//! Zero-dependency SIGINT/SIGTERM shutdown flag + SIGHUP reload flag.
//!
//! `bold serve-http` and `bold train-dist` are long-running foreground
//! processes; Ctrl-C under load must trigger the same graceful drain as
//! `POST /admin/shutdown` instead of tearing connections mid-response, and
//! `kill -HUP` must trigger a `--model-dir` re-scan (hot checkpoint
//! reload, DESIGN.md §Model-Lifecycle) without touching in-flight
//! requests. The offline registry has no `signal-hook` or `libc` crate,
//! so on Unix we declare the two C symbols we need (`signal`, `raise` —
//! already linked into every std binary) ourselves and install handlers
//! that do the only async-signal-safe thing possible: set a static
//! [`AtomicBool`]. The main loop polls [`triggered`] / [`take_hup`] at
//! its own cadence.
//!
//! Non-Unix targets compile to a no-op installer so the call sites stay
//! unconditional (and [`take_hup`] simply never fires).

use std::sync::atomic::{AtomicBool, Ordering};

static TRIGGERED: AtomicBool = AtomicBool::new(false);
static HUP: AtomicBool = AtomicBool::new(false);

#[cfg(unix)]
mod imp {
    use super::*;

    pub const SIGHUP: i32 = 1;
    pub const SIGINT: i32 = 2;
    pub const SIGTERM: i32 = 15;

    // `sighandler_t` is `void (*)(int)`; `signal(2)` and `raise(3)` are in
    // every libc that std itself links against, so no crate is needed.
    extern "C" {
        fn signal(signum: i32, handler: usize) -> usize;
        fn raise(signum: i32) -> i32;
    }

    extern "C" fn on_signal(_signum: i32) {
        // Only async-signal-safe operation: a relaxed atomic store. The
        // poller upgrades visibility with an Acquire load.
        TRIGGERED.store(true, Ordering::Release);
    }

    extern "C" fn on_hup(_signum: i32) {
        HUP.store(true, Ordering::Release);
    }

    pub fn install() {
        unsafe {
            signal(SIGINT, on_signal as usize);
            signal(SIGTERM, on_signal as usize);
        }
    }

    pub fn install_hup() {
        unsafe {
            signal(SIGHUP, on_hup as usize);
        }
    }

    /// Deliver `signum` to the current process (test hook).
    pub fn raise_signal(signum: i32) {
        unsafe {
            raise(signum);
        }
    }
}

#[cfg(not(unix))]
mod imp {
    pub const SIGHUP: i32 = 1;
    pub const SIGINT: i32 = 2;
    pub const SIGTERM: i32 = 15;
    pub fn install() {}
    pub fn install_hup() {}
    pub fn raise_signal(_signum: i32) {}
}

pub use imp::{SIGHUP, SIGINT, SIGTERM};

/// Install the SIGINT/SIGTERM handler. Idempotent; call once at the top of
/// a long-running command before entering its poll loop.
pub fn install_shutdown_handler() {
    imp::install();
}

/// Install the SIGHUP handler ([`take_hup`] observes deliveries).
/// Idempotent; `serve-http` installs it when `--model-dir` is given. As a
/// side effect a HUP no longer kills the process (the default
/// disposition), which is exactly what a hot-reload daemon wants.
pub fn install_reload_handler() {
    imp::install_hup();
}

/// True once SIGINT or SIGTERM has been received (sticky).
pub fn triggered() -> bool {
    TRIGGERED.load(Ordering::Acquire)
}

/// Consume a pending SIGHUP: true at most once per delivery
/// (edge-triggered — coalesced signals trigger one re-scan, which is
/// fine because a re-scan examines every checkpoint anyway).
pub fn take_hup() -> bool {
    HUP.swap(false, Ordering::AcqRel)
}

/// Reset the flags (tests only — production commands exit after a trigger).
pub fn reset() {
    TRIGGERED.store(false, Ordering::Release);
    HUP.store(false, Ordering::Release);
}

/// Send `signum` to the current process. Exposed for the integration tests
/// that prove Ctrl-C drains gracefully without spawning a child process.
pub fn raise_for_test(signum: i32) {
    imp::raise_signal(signum);
}

#[cfg(all(test, unix))]
mod tests {
    use super::*;

    // Signal disposition is process-global state, so keep everything in
    // one test to avoid cross-test races under the parallel harness.
    #[test]
    fn handler_sets_sticky_flag_for_int_and_term() {
        install_shutdown_handler();
        install_reload_handler();
        reset();
        assert!(!triggered());

        raise_for_test(SIGTERM);
        assert!(triggered(), "SIGTERM must set the flag");
        // Sticky: repeated polls still see it.
        assert!(triggered());

        reset();
        raise_for_test(SIGINT);
        assert!(triggered(), "SIGINT must set the flag");

        // SIGHUP is a separate, edge-triggered flag: it must not touch
        // the shutdown flag, and take_hup() consumes it.
        reset();
        assert!(!take_hup());
        raise_for_test(SIGHUP);
        assert!(!triggered(), "HUP is reload, not shutdown");
        assert!(take_hup(), "first poll consumes the delivery");
        assert!(!take_hup(), "edge-triggered: second poll sees nothing");
        reset();
    }
}
