//! Small self-contained utilities: deterministic PRNG, a wall-clock timer
//! and the persistent intra-op worker pool.
//!
//! The offline crate registry has no `rand`, so we ship a SplitMix64-seeded
//! xoshiro256** generator — more than enough statistical quality for data
//! synthesis, init and property tests, and fully reproducible across runs.
//! Likewise no `rayon`: [`pool`] is a std-only persistent thread pool that
//! every hot kernel shards over (DESIGN.md §Parallelism).

pub mod crc32;
pub mod pool;
pub mod rng;
pub mod signal;
pub mod timer;

pub use crc32::{crc32, Crc32};
pub use rng::Rng;
pub use timer::Timer;
