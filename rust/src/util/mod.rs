//! Small self-contained utilities: deterministic PRNG and a wall-clock timer.
//!
//! The offline crate registry has no `rand`, so we ship a SplitMix64-seeded
//! xoshiro256** generator — more than enough statistical quality for data
//! synthesis, init and property tests, and fully reproducible across runs.

pub mod rng;
pub mod timer;

pub use rng::Rng;
pub use timer::Timer;
