//! The variation calculus (Definitions 3.7–3.12, Theorem 3.11,
//! Propositions A.4–A.6) over function *tables*, so the chain rules can be
//! checked on arbitrary Boolean functions — this is the machinery behind
//! the property tests that validate the paper's math, and the formal
//! justification for the closed-form backward rules used by `nn::`.

use super::bool3::{B3, F, T};

/// A univariate function 𝔹 → 𝕄 represented by its value table
/// (`at_t` = f(T), `at_f` = f(F)).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BoolFn {
    pub at_t: B3,
    pub at_f: B3,
}

impl BoolFn {
    pub fn new(at_t: B3, at_f: B3) -> Self {
        BoolFn { at_t, at_f }
    }

    #[inline]
    pub fn eval(&self, x: B3) -> B3 {
        match x {
            T => self.at_t,
            F => self.at_f,
            B3::Zero => B3::Zero,
        }
    }

    /// Pointwise negation ¬f.
    pub fn not(&self) -> BoolFn {
        BoolFn::new(self.at_t.not(), self.at_f.not())
    }

    /// Composition g ∘ f for f, g : 𝔹 → 𝔹.
    pub fn compose(&self, g: &BoolFn) -> BoolFn {
        BoolFn::new(g.eval(self.at_t), g.eval(self.at_f))
    }

    /// All 9 functions 𝔹 → 𝕄 (and the 4 with range 𝔹 among them).
    pub fn all_m() -> Vec<BoolFn> {
        use super::bool3::ALL3;
        let mut v = Vec::new();
        for &a in &ALL3 {
            for &b in &ALL3 {
                v.push(BoolFn::new(a, b));
            }
        }
        v
    }

    /// All 4 functions 𝔹 → 𝔹.
    pub fn all_b() -> Vec<BoolFn> {
        use super::bool3::ALL2;
        let mut v = Vec::new();
        for &a in &ALL2 {
            for &b in &ALL2 {
                v.push(BoolFn::new(a, b));
            }
        }
        v
    }
}

/// The variation f'(x) of Definition 3.8:
/// f'(x) = xnor(δ(x → ¬x), δf(x → ¬x)).
pub fn variation(f: &BoolFn, x: B3) -> B3 {
    if !x.is_bool() {
        return B3::Zero;
    }
    let dx = x.delta_to(x.not());
    let df = f.eval(x).delta_to(f.eval(x.not()));
    dx.xnor(df)
}

/// Partial variation of a multivariate f : 𝔹ⁿ → 𝕄 w.r.t. coordinate `i`
/// (Definition 3.12), with `f` given as a closure over the full input.
pub fn variation_multi<Fn_: Fn(&[B3]) -> B3>(f: Fn_, x: &[B3], i: usize) -> B3 {
    let xi = x[i];
    if !xi.is_bool() {
        return B3::Zero;
    }
    let mut xneg = x.to_vec();
    xneg[i] = xi.not();
    let dx = xi.delta_to(xi.not());
    let df = f(x).delta_to(f(&xneg));
    dx.xnor(df)
}

/// Chain rule for 𝔹 → 𝔹 → 𝕄 (Theorem 3.11(4) / Proposition A.6(1)):
/// (g ∘ f)'(x) = xnor(g'(f(x)), f'(x)).
pub fn chain_bb(f: &BoolFn, g: &BoolFn, x: B3) -> B3 {
    variation(g, f.eval(x)).xnor(variation(f, x))
}

/// Chain rule for 𝔹 → ℤ → 𝕄 (Theorem 3.11(5) / Proposition A.6(2)).
///
/// `f` is given by its two integer values, `g'` by a closure returning the
/// ℤ-variation g'(z) = δg(z → z+1) (Definition 3.10). The theorem requires
/// |f'(x)| ≤ 1 and g'(f(x)) = g'(f(x)−1); the caller is responsible for
/// checking applicability (the tests verify the conclusion under it).
pub fn chain_bz<G: Fn(i64) -> B3>(f_t: i64, f_f: i64, g_var: G, x: B3) -> B3 {
    let fx = match x {
        T => f_t,
        F => f_f,
        B3::Zero => return B3::Zero,
    };
    // f'(x) in ℤ-embedded form: xnor(δ(x→¬x), f(¬x) − f(x)).
    let fnx = match x {
        T => f_f,
        F => f_t,
        B3::Zero => unreachable!(),
    };
    let dxe: i64 = match x {
        T => -1, // δ(T→F) = F
        F => 1,  // δ(F→T) = T
        B3::Zero => unreachable!(),
    };
    let fprime = dxe * (fnx - fx);
    let fp_logic = super::bool3::project(fprime.clamp(-1, 1) as i32);
    g_var(fx).xnor(fp_logic)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::logic::bool3::{embed, ALL2};

    /// Table 8 of the paper: f(x) = xor(a, x) has f'(x) = ¬a.
    #[test]
    fn table8_xor_variation() {
        for &a in &ALL2 {
            let f = BoolFn::new(T.xor(a), F.xor(a));
            for &x in &ALL2 {
                assert_eq!(variation(&f, x), a.not(), "a={a:?} x={x:?}");
            }
        }
    }

    /// Example 3.14: xnor(x, a)' = a (via Theorem 3.11(1)).
    #[test]
    fn xnor_variation_is_a() {
        for &a in &ALL2 {
            let f = BoolFn::new(T.xnor(a), F.xnor(a));
            for &x in &ALL2 {
                assert_eq!(variation(&f, x), a);
            }
        }
    }

    /// Theorem 3.11(1): (¬f)' = ¬f', exhaustively over all f : 𝔹 → 𝔹.
    #[test]
    fn negation_rule_exhaustive() {
        for f in BoolFn::all_b() {
            for &x in &ALL2 {
                assert_eq!(variation(&f.not(), x), variation(&f, x).not());
            }
        }
    }

    /// Theorem 3.11(4): chain rule over all 16 pairs (f, g) of 𝔹 → 𝔹.
    #[test]
    fn chain_rule_bb_exhaustive() {
        for f in BoolFn::all_b() {
            for g in BoolFn::all_b() {
                for &x in &ALL2 {
                    let lhs = variation(&f.compose(&g), x);
                    let rhs = chain_bb(&f, &g, x);
                    assert_eq!(lhs, rhs, "f={f:?} g={g:?} x={x:?}");
                }
            }
        }
    }

    /// Proposition A.4(1): δf(x → y) = xnor(δ(x → y), f'(x)).
    #[test]
    fn delta_f_identity() {
        for f in BoolFn::all_b() {
            for &x in &ALL2 {
                for &y in &ALL2 {
                    let lhs = f.eval(x).delta_to(f.eval(y));
                    let rhs = x.delta_to(y).xnor(variation(&f, x));
                    assert_eq!(lhs, rhs);
                }
            }
        }
    }

    /// Definition 3.12 partial variation on a concrete 3-input majority.
    #[test]
    fn multivariate_majority_variation() {
        let maj = |xs: &[B3]| -> B3 {
            let s: i32 = xs.iter().map(|&b| embed(b)).sum();
            crate::logic::bool3::project(s)
        };
        // If the other two disagree, x_i decides: variation is T
        // (the output moves with x_i).
        assert_eq!(variation_multi(maj, &[T, T, F], 0), T);
        assert_eq!(variation_multi(maj, &[F, F, T], 0), T);
        // If the other two agree, flipping x_i cannot change the output: 0.
        assert_eq!(variation_multi(maj, &[T, T, F], 2), B3::Zero);
        assert_eq!(variation_multi(maj, &[T, F, F], 0), B3::Zero);
    }

    /// Theorem 3.11(5) on g(z) = z (identity, g' ≡ T) and f counting-like.
    #[test]
    fn chain_rule_bz() {
        // f: T ↦ 3, F ↦ 2 (|f'| = 1), g' ≡ T (monotone increasing g).
        let got = chain_bz(3, 2, |_| T, T);
        // f'(T) = xnor(δ(T→F), 2−3) = xnor(F, F-ish) ... direct: f
        // decreases when x decreases: same direction ⇒ f' = T; chain = T.
        assert_eq!(got, T);
        // Decreasing g (g' ≡ F) flips the sign.
        assert_eq!(chain_bz(3, 2, |_| F, T), F);
        // Constant f (f' = 0) kills the variation.
        assert_eq!(chain_bz(5, 5, |_| T, T), B3::Zero);
    }

    /// Embedded-domain consistency: e(f'(x)) equals the sign of the
    /// discrete derivative of e∘f in the direction of increasing e(x).
    #[test]
    fn variation_matches_embedded_slope() {
        for f in BoolFn::all_b() {
            // slope = (e(f(T)) − e(f(F))) / (e(T) − e(F)) ∈ {−1, 0, 1}
            let slope = (embed(f.at_t) - embed(f.at_f)) / 2;
            for &x in &ALL2 {
                assert_eq!(embed(variation(&f, x)), slope.signum() * slope.abs(),
                    "f={f:?}");
            }
        }
    }
}
