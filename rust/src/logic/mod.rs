//! The paper's mathematical foundation (§3.2, Appendix A.1):
//! three-valued logic 𝕄 = 𝔹 ∪ {0}, mixed-type connectives, the Boolean
//! *variation* δ and the variation calculus with its chain rules
//! (Theorem 3.11, Propositions A.2–A.6).
//!
//! This module is the executable form of the math: every definition and
//! theorem in Appendix A.1 has a direct counterpart here, and the unit /
//! property tests check the theorem statements on exhaustive or random
//! inputs (including the Table 8 truth table).

mod bool3;
mod variation;

pub use bool3::{embed, mixed_xnor, mixed_xor, project, B3, ALL2, ALL3, F, T, ZERO};
pub use variation::{chain_bb, chain_bz, variation, variation_multi, BoolFn};
