//! Three-valued logic 𝕄 = {T, 0, F} (Definition 3.1) and the numeric
//! embedding/projection maps of Definition A.1:
//!
//! p : ℕ → 𝕃  projects a number onto its logic value (Definition 3.3),
//! e : 𝕃 → ℕ  embeds T ↦ +1, 0 ↦ 0, F ↦ −1.
//!
//! Proposition A.2(2) makes (𝔹, xnor) ≅ ({±1}, ×): this isomorphism is the
//! bridge between the bit-level engine (tensor::bitmatrix) and the ±1
//! arithmetic used by the L2 jax graphs — tested below.

/// Element of the three-valued logic 𝕄 (Definition 3.1).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum B3 {
    /// TRUE.
    T,
    /// The absorbing "no information" value adjoined to 𝔹.
    Zero,
    /// FALSE.
    F,
}

pub use B3::{F, T};
/// Convenience alias: `B3::Zero` under the paper's symbol `0`.
pub const ZERO: B3 = B3::Zero;

impl B3 {
    /// Negation: ¬T = F, ¬F = T, ¬0 = 0 (Definition 3.1).
    #[inline]
    pub fn not(self) -> B3 {
        match self {
            T => F,
            F => T,
            B3::Zero => B3::Zero,
        }
    }

    /// True iff the value is in 𝔹 (not the adjoined 0).
    #[inline]
    pub fn is_bool(self) -> bool {
        !matches!(self, B3::Zero)
    }

    /// Magnitude |x| (Definition 3.4): 0 for 0, 1 otherwise.
    #[inline]
    pub fn magnitude(self) -> i32 {
        if self.is_bool() { 1 } else { 0 }
    }

    /// XNOR in 𝕄: equality on 𝔹, 0 if either operand is 0 (Definition 3.1).
    #[inline]
    pub fn xnor(self, other: B3) -> B3 {
        match (self, other) {
            (B3::Zero, _) | (_, B3::Zero) => B3::Zero,
            (a, b) if a == b => T,
            _ => F,
        }
    }

    /// XOR in 𝕄 (¬xnor on 𝔹, 0-absorbing).
    #[inline]
    pub fn xor(self, other: B3) -> B3 {
        self.xnor(other).not()
    }

    /// AND in 𝕄.
    #[inline]
    pub fn and(self, other: B3) -> B3 {
        match (self, other) {
            (B3::Zero, _) | (_, B3::Zero) => B3::Zero,
            (T, T) => T,
            _ => F,
        }
    }

    /// OR in 𝕄.
    #[inline]
    pub fn or(self, other: B3) -> B3 {
        match (self, other) {
            (B3::Zero, _) | (_, B3::Zero) => B3::Zero,
            (F, F) => F,
            _ => T,
        }
    }

    /// Order relation of Definition 3.6 extended to 𝕄: F < 0 < T.
    #[inline]
    pub fn cmp_logic(self, other: B3) -> std::cmp::Ordering {
        fn rank(x: B3) -> i32 {
            match x {
                F => -1,
                B3::Zero => 0,
                T => 1,
            }
        }
        rank(self).cmp(&rank(other))
    }

    /// The variation δ(a → b) of Definition 3.7: T if b > a, 0 if equal,
    /// F if b < a.
    #[inline]
    pub fn delta_to(self, b: B3) -> B3 {
        match self.cmp_logic(b) {
            std::cmp::Ordering::Less => T,    // b > a
            std::cmp::Ordering::Equal => B3::Zero,
            std::cmp::Ordering::Greater => F, // b < a
        }
    }
}

/// Embedding e : 𝕃 → ℕ of Definition A.1 — e(T)=+1, e(0)=0, e(F)=−1.
#[inline]
pub fn embed(x: B3) -> i32 {
    match x {
        T => 1,
        B3::Zero => 0,
        F => -1,
    }
}

/// Projection p : ℕ → 𝕃 of Definition A.1 — sign of the number.
#[inline]
pub fn project(x: i32) -> B3 {
    match x.cmp(&0) {
        std::cmp::Ordering::Greater => T,
        std::cmp::Ordering::Equal => B3::Zero,
        std::cmp::Ordering::Less => F,
    }
}

/// Mixed-type xnor of Definition 3.5: |c| = |a||b| and
/// c_logic = xnor(a_logic, b_logic). With a Boolean operand the result is
/// `e(a)·x` (Proposition A.3(1)); numeric×numeric degenerates to the
/// product (Proposition A.3(2)).
#[inline]
pub fn mixed_xnor(a: B3, x: f32) -> f32 {
    embed(a) as f32 * x
}

/// Mixed-type xor (Proposition A.3(5)): xor(a, x) = −xnor(a, x).
#[inline]
pub fn mixed_xor(a: B3, x: f32) -> f32 {
    -mixed_xnor(a, x)
}

/// All three values, for exhaustive truth-table tests.
pub const ALL3: [B3; 3] = [T, B3::Zero, F];
/// The two Boolean values.
pub const ALL2: [B3; 2] = [T, F];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn negation_table() {
        assert_eq!(T.not(), F);
        assert_eq!(F.not(), T);
        assert_eq!(ZERO.not(), ZERO);
    }

    #[test]
    fn xnor_restricted_to_bool_is_equality() {
        for &a in &ALL2 {
            for &b in &ALL2 {
                assert_eq!(a.xnor(b), if a == b { T } else { F });
            }
        }
    }

    #[test]
    fn zero_absorbs_all_connectives() {
        for &a in &ALL3 {
            assert_eq!(a.xnor(ZERO), ZERO);
            assert_eq!(ZERO.xnor(a), ZERO);
            assert_eq!(a.xor(ZERO), ZERO);
            assert_eq!(a.and(ZERO), ZERO);
            assert_eq!(a.or(ZERO), ZERO);
        }
    }

    #[test]
    fn embedding_isomorphism_prop_a2() {
        // Prop A.2(2): e(xnor(a,b)) = e(a)·e(b) on all of 𝕄.
        for &a in &ALL3 {
            for &b in &ALL3 {
                assert_eq!(embed(a.xnor(b)), embed(a) * embed(b), "{a:?} {b:?}");
                // and xor is the negated product
                assert_eq!(embed(a.xor(b)), -embed(a) * embed(b));
            }
        }
    }

    #[test]
    fn projection_embedding_roundtrip() {
        for &a in &ALL3 {
            assert_eq!(project(embed(a)), a);
        }
        // Prop A.2(1): p(xy) = xnor(p(x), p(y)).
        for x in -3..=3 {
            for y in -3..=3 {
                assert_eq!(project(x * y), project(x).xnor(project(y)));
            }
        }
    }

    #[test]
    fn variation_definition_3_7() {
        assert_eq!(F.delta_to(T), T);
        assert_eq!(T.delta_to(F), F);
        assert_eq!(T.delta_to(T), ZERO);
        assert_eq!(F.delta_to(F), ZERO);
    }

    #[test]
    fn mixed_type_ops_prop_a3() {
        let sign3 = |v: f32| project(if v > 0.0 { 1 } else if v < 0.0 { -1 } else { 0 });
        for &a in &ALL3 {
            for x in [-2.5f32, -1.0, 0.0, 0.5, 3.0] {
                let v = mixed_xnor(a, x);
                // Definition 3.5: |c| = |a||x| and c_logic = xnor(a_logic, x_logic).
                assert_eq!(v.abs(), a.magnitude() as f32 * x.abs());
                assert_eq!(sign3(v), a.xnor(sign3(x)), "logic value of mixed xnor");
                // Prop A.3(5): xor = −xnor.
                assert_eq!(mixed_xor(a, x), -v);
            }
        }
    }

    #[test]
    fn order_relation() {
        assert!(F.cmp_logic(T).is_lt());
        assert!(T.cmp_logic(F).is_gt());
        assert!(ZERO.cmp_logic(T).is_lt());
        assert!(F.cmp_logic(ZERO).is_lt());
    }
}
