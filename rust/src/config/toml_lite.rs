//! TOML-subset parser: sections, scalars, flat arrays, `#` comments.

use std::collections::BTreeMap;
use std::fmt;

/// Parse/typing error with a human-readable message.
#[derive(Debug, Clone)]
pub struct ConfigError {
    pub msg: String,
}

impl ConfigError {
    pub fn new(msg: impl Into<String>) -> Self {
        ConfigError { msg: msg.into() }
    }
}

impl fmt::Display for ConfigError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "config error: {}", self.msg)
    }
}

impl std::error::Error for ConfigError {}

/// A parsed value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Array(Vec<Value>),
}

impl Value {
    pub fn as_str(&self) -> Result<&str, ConfigError> {
        match self {
            Value::Str(s) => Ok(s),
            other => Err(ConfigError::new(format!("expected string, got {other:?}"))),
        }
    }

    pub fn as_usize(&self) -> Result<usize, ConfigError> {
        match self {
            Value::Int(i) if *i >= 0 => Ok(*i as usize),
            other => Err(ConfigError::new(format!("expected non-negative int, got {other:?}"))),
        }
    }

    pub fn as_f32(&self) -> Result<f32, ConfigError> {
        match self {
            Value::Float(f) => Ok(*f as f32),
            Value::Int(i) => Ok(*i as f32),
            other => Err(ConfigError::new(format!("expected number, got {other:?}"))),
        }
    }

    pub fn as_bool(&self) -> Result<bool, ConfigError> {
        match self {
            Value::Bool(b) => Ok(*b),
            other => Err(ConfigError::new(format!("expected bool, got {other:?}"))),
        }
    }
}

fn parse_scalar(s: &str, line_no: usize) -> Result<Value, ConfigError> {
    let s = s.trim();
    if s.starts_with('"') && s.ends_with('"') && s.len() >= 2 {
        return Ok(Value::Str(s[1..s.len() - 1].to_string()));
    }
    if s == "true" {
        return Ok(Value::Bool(true));
    }
    if s == "false" {
        return Ok(Value::Bool(false));
    }
    if let Ok(i) = s.parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = s.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    Err(ConfigError::new(format!("line {line_no}: cannot parse value '{s}'")))
}

/// Parsed document: (section, key) → value. Keys before any section go
/// into the "" section.
#[derive(Debug, Default)]
pub struct ConfigDoc {
    map: BTreeMap<(String, String), Value>,
}

impl ConfigDoc {
    pub fn parse(text: &str) -> Result<Self, ConfigError> {
        let mut doc = ConfigDoc::default();
        let mut section = String::new();
        for (i, raw) in text.lines().enumerate() {
            let line_no = i + 1;
            // strip comments (naive: assumes no '#' inside strings we care about)
            let line = match raw.find('#') {
                Some(p) if !raw[..p].contains('"') || raw[..p].matches('"').count() % 2 == 0 => {
                    &raw[..p]
                }
                _ => raw,
            };
            let line = line.trim();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix('[').and_then(|l| l.strip_suffix(']')) {
                section = name.trim().to_string();
                continue;
            }
            let eq = line
                .find('=')
                .ok_or_else(|| ConfigError::new(format!("line {line_no}: missing '='")))?;
            let key = line[..eq].trim().to_string();
            let val_s = line[eq + 1..].trim();
            let value = if val_s.starts_with('[') && val_s.ends_with(']') {
                let inner = &val_s[1..val_s.len() - 1];
                let items: Result<Vec<Value>, _> = inner
                    .split(',')
                    .map(str::trim)
                    .filter(|s| !s.is_empty())
                    .map(|s| parse_scalar(s, line_no))
                    .collect();
                Value::Array(items?)
            } else {
                parse_scalar(val_s, line_no)?
            };
            doc.map.insert((section.clone(), key), value);
        }
        Ok(doc)
    }

    pub fn get(&self, section: &str, key: &str) -> Option<&Value> {
        self.map.get(&(section.to_string(), key.to_string()))
    }

    pub fn sections(&self) -> Vec<String> {
        let mut v: Vec<String> = self.map.keys().map(|(s, _)| s.clone()).collect();
        v.dedup();
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_all_types() {
        let doc = ConfigDoc::parse(
            r#"
# top comment
name = "bold"          # trailing comment
[train]
steps = 300
lr = 1.5e-3
flag = true
dims = [1, 2, 3]
tags = ["a", "b"]
"#,
        )
        .unwrap();
        assert_eq!(doc.get("", "name").unwrap().as_str().unwrap(), "bold");
        assert_eq!(doc.get("train", "steps").unwrap().as_usize().unwrap(), 300);
        assert!((doc.get("train", "lr").unwrap().as_f32().unwrap() - 0.0015).abs() < 1e-7);
        assert!(doc.get("train", "flag").unwrap().as_bool().unwrap());
        match doc.get("train", "dims").unwrap() {
            Value::Array(v) => assert_eq!(v.len(), 3),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn rejects_garbage() {
        assert!(ConfigDoc::parse("key value\n").is_err());
        assert!(ConfigDoc::parse("[s]\nk = @@\n").is_err());
    }

    #[test]
    fn type_errors_are_reported() {
        let doc = ConfigDoc::parse("[t]\nk = 5\n").unwrap();
        assert!(doc.get("t", "k").unwrap().as_str().is_err());
        assert!(doc.get("t", "k").unwrap().as_bool().is_err());
        assert_eq!(doc.get("t", "k").unwrap().as_f32().unwrap(), 5.0);
    }
}
