//! Configuration system: a small TOML-subset parser (offline registry has
//! no serde/toml) plus the typed experiment configs the launcher consumes.
//!
//! Supported syntax: `[section]` headers, `key = value` with string
//! (quoted), bool, integer, float, and flat arrays of numbers/strings.
//! Comments with `#`. That covers every config this project ships.

mod toml_lite;

pub use toml_lite::{ConfigDoc, ConfigError, Value as ConfigValue};

/// Training run configuration (populated from a config file + CLI
/// overrides by the launcher).
#[derive(Debug, Clone)]
pub struct TrainConfig {
    /// Model family: "mlp" | "vgg" | "resnet" | "edsr" | "segnet" | "bert".
    pub model: String,
    /// Method: "bold" | "bold_bn" | "fp" | "binaryconnect" | "binarynet"
    /// | "xnornet".
    pub method: String,
    pub steps: usize,
    pub batch: usize,
    /// Boolean-optimizer accumulation rate η (paper: 12 without BN, 150
    /// with BN for VGG; scaled tasks use smaller values).
    pub lr_bool: f32,
    /// Adam learning rate for the FP parameters (paper: 1e-3).
    pub lr_fp: f32,
    pub seed: u64,
    /// Dataset size (synthetic).
    pub train_size: usize,
    pub val_size: usize,
    /// Input spatial size / sequence length, model-dependent.
    pub hw: usize,
    pub classes: usize,
    pub width_mult: f32,
    /// Parallel training workers (batch-parallel vote aggregation).
    pub workers: usize,
    /// Cosine schedule on both optimizers (paper Appendix D.1.1).
    pub cosine: bool,
    pub log_every: usize,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            model: "vgg".into(),
            method: "bold".into(),
            steps: 300,
            batch: 64,
            lr_bool: 12.0,
            lr_fp: 1e-3,
            seed: 42,
            train_size: 2048,
            val_size: 512,
            hw: 16,
            classes: 10,
            width_mult: 0.125,
            workers: 1,
            cosine: true,
            log_every: 25,
        }
    }
}

impl TrainConfig {
    /// Build from a parsed config document (section `[train]`).
    pub fn from_doc(doc: &ConfigDoc) -> Result<Self, ConfigError> {
        let mut cfg = TrainConfig::default();
        let get = |k: &str| doc.get("train", k);
        if let Some(v) = get("model") {
            cfg.model = v.as_str()?.to_string();
        }
        if let Some(v) = get("method") {
            cfg.method = v.as_str()?.to_string();
        }
        if let Some(v) = get("steps") {
            cfg.steps = v.as_usize()?;
        }
        if let Some(v) = get("batch") {
            cfg.batch = v.as_usize()?;
        }
        if let Some(v) = get("lr_bool") {
            cfg.lr_bool = v.as_f32()?;
        }
        if let Some(v) = get("lr_fp") {
            cfg.lr_fp = v.as_f32()?;
        }
        if let Some(v) = get("seed") {
            cfg.seed = v.as_usize()? as u64;
        }
        if let Some(v) = get("train_size") {
            cfg.train_size = v.as_usize()?;
        }
        if let Some(v) = get("val_size") {
            cfg.val_size = v.as_usize()?;
        }
        if let Some(v) = get("hw") {
            cfg.hw = v.as_usize()?;
        }
        if let Some(v) = get("classes") {
            cfg.classes = v.as_usize()?;
        }
        if let Some(v) = get("width_mult") {
            cfg.width_mult = v.as_f32()?;
        }
        if let Some(v) = get("workers") {
            cfg.workers = v.as_usize()?;
        }
        if let Some(v) = get("cosine") {
            cfg.cosine = v.as_bool()?;
        }
        if let Some(v) = get("log_every") {
            cfg.log_every = v.as_usize()?;
        }
        Ok(cfg)
    }

    pub fn from_file(path: &str) -> Result<Self, ConfigError> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| ConfigError::new(format!("read {path}: {e}")))?;
        let doc = ConfigDoc::parse(&text)?;
        Self::from_doc(&doc)
    }

    /// Apply `--key value` CLI overrides (key names match config keys).
    pub fn apply_override(&mut self, key: &str, value: &str) -> Result<(), ConfigError> {
        let bad = |k: &str, v: &str| ConfigError::new(format!("bad value '{v}' for --{k}"));
        match key {
            "model" => self.model = value.to_string(),
            "method" => self.method = value.to_string(),
            "steps" => self.steps = value.parse().map_err(|_| bad(key, value))?,
            "batch" => self.batch = value.parse().map_err(|_| bad(key, value))?,
            "lr_bool" => self.lr_bool = value.parse().map_err(|_| bad(key, value))?,
            "lr_fp" => self.lr_fp = value.parse().map_err(|_| bad(key, value))?,
            "seed" => self.seed = value.parse().map_err(|_| bad(key, value))?,
            "train_size" => self.train_size = value.parse().map_err(|_| bad(key, value))?,
            "val_size" => self.val_size = value.parse().map_err(|_| bad(key, value))?,
            "hw" => self.hw = value.parse().map_err(|_| bad(key, value))?,
            "classes" => self.classes = value.parse().map_err(|_| bad(key, value))?,
            "width_mult" => self.width_mult = value.parse().map_err(|_| bad(key, value))?,
            "workers" => self.workers = value.parse().map_err(|_| bad(key, value))?,
            "cosine" => self.cosine = value.parse().map_err(|_| bad(key, value))?,
            "log_every" => self.log_every = value.parse().map_err(|_| bad(key, value))?,
            _ => return Err(ConfigError::new(format!("unknown option --{key}"))),
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_doc_and_overrides() {
        let doc = ConfigDoc::parse(
            "# experiment\n[train]\nmodel = \"resnet\"\nsteps = 100\nlr_bool = 6.5\ncosine = false\n",
        )
        .unwrap();
        let mut cfg = TrainConfig::from_doc(&doc).unwrap();
        assert_eq!(cfg.model, "resnet");
        assert_eq!(cfg.steps, 100);
        assert!((cfg.lr_bool - 6.5).abs() < 1e-6);
        assert!(!cfg.cosine);
        cfg.apply_override("batch", "32").unwrap();
        assert_eq!(cfg.batch, 32);
        assert!(cfg.apply_override("nope", "1").is_err());
    }
}
