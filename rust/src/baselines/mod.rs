//! BNN baselines (paper §2, Table 1): BINARYCONNECT, BINARYNET and
//! XNOR-NET, implemented exactly as the paper characterizes them —
//! *latent-weight* training: FP latent weights, sign binarization in the
//! forward, straight-through-estimator (STE) gradients, Adam updates.
//!
//! These exist to regenerate the comparison rows of Fig. 1 / Table 2 /
//! Table 5 (accuracy + training-energy): the whole point is that they keep
//! an FP copy of every weight and FP gradients throughout training, which
//! is what the energy model charges them for.

mod latent;

pub use latent::{bnn_vgg_small, BnnKind, LatentBinConv2d, LatentBinLinear, SignSTE};
