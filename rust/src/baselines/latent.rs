//! Latent-weight binarized layers (the classic BNN recipe).
//!
//! Forward: w_bin = sign(w_fp) (optionally scaled by the XNOR-Net
//! per-output α = mean|w_fp|); activations optionally sign-binarized by
//! [`SignSTE`]. Backward: STE — the gradient w.r.t. the binarized tensor is
//! passed to the latent tensor, masked by the hard-tanh clip 1{|w| ≤ 1}
//! (Courbariaux et al.). Latent weights are `ParamRef::Real` → Adam.

use crate::nn::{Layer, ParamRef, ParamStore, Value};
use crate::tensor::Tensor;
use crate::util::Rng;

/// Which baseline recipe a network follows (paper Table 1 rows).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BnnKind {
    /// 1-bit weights, 32-bit activations (Courbariaux et al. 2015).
    BinaryConnect,
    /// 1-bit weights and activations (Hubara et al. 2016).
    BinaryNet,
    /// 1-bit weights (α-scaled) and activations (Rastegari et al. 2016).
    XnorNet,
}

impl BnnKind {
    pub fn binarize_activations(&self) -> bool {
        !matches!(self, BnnKind::BinaryConnect)
    }

    pub fn scale_weights(&self) -> bool {
        matches!(self, BnnKind::XnorNet)
    }

    /// (weight, activation) bitwidths for the energy model.
    pub fn bitwidths(&self) -> (u32, u32) {
        match self {
            BnnKind::BinaryConnect => (1, 32),
            _ => (1, 1),
        }
    }
}

fn sign(v: f32) -> f32 {
    if v >= 0.0 { 1.0 } else { -1.0 }
}

/// Binarize the latent weights row-wise: w_bin[j,·] = α_j · sign(w_fp[j,·]).
fn binarize_weights(w_fp: &Tensor, scale: bool) -> Tensor {
    let (r, c) = (w_fp.rows(), w_fp.cols());
    let mut out = Tensor::zeros(&[r, c]);
    for j in 0..r {
        let row = &w_fp.data[j * c..(j + 1) * c];
        let alpha = if scale {
            row.iter().map(|v| v.abs()).sum::<f32>() / c as f32
        } else {
            1.0
        };
        for i in 0..c {
            out.data[j * c + i] = alpha * sign(row[i]);
        }
    }
    out
}

/// Sign activation with hard-tanh STE backward: z·1{|x| ≤ 1}.
pub struct SignSTE {
    name: String,
    cache_x: Option<Tensor>,
}

impl SignSTE {
    pub fn new(name: &str) -> Self {
        SignSTE { name: name.to_string(), cache_x: None }
    }
}

impl Layer for SignSTE {
    fn forward(&mut self, x: Value, train: bool) -> Value {
        let t = x.to_f32();
        let y = t.sign_pm1();
        if train {
            self.cache_x = Some(t);
        }
        Value::F32(y)
    }

    fn backward(&mut self, z: Tensor, _store: &mut ParamStore) -> Tensor {
        let x = self.cache_x.as_ref().expect("backward before forward");
        Tensor {
            shape: z.shape.clone(),
            data: z
                .data
                .iter()
                .zip(&x.data)
                .map(|(&zv, &xv)| if xv.abs() <= 1.0 { zv } else { 0.0 })
                .collect(),
        }
    }

    fn name(&self) -> String {
        self.name.clone()
    }
}

/// Conv2d with latent FP weights binarized in the forward.
pub struct LatentBinConv2d {
    pub c_in: usize,
    pub c_out: usize,
    pub k: usize,
    pub stride: usize,
    pub pad: usize,
    pub w_fp: Tensor,
    pub scale: bool,
    name: String,
    cache_cols: Option<Tensor>,
    cache_dims: Option<(usize, usize, usize, usize, usize)>,
    cache_wbin: Option<Tensor>,
}

impl LatentBinConv2d {
    pub fn new(
        name: &str,
        c_in: usize,
        c_out: usize,
        k: usize,
        stride: usize,
        pad: usize,
        scale: bool,
        rng: &mut Rng,
    ) -> Self {
        let fanin = c_in * k * k;
        LatentBinConv2d {
            c_in,
            c_out,
            k,
            stride,
            pad,
            w_fp: Tensor::randn(&[c_out, fanin], 0.3, rng),
            scale,
            name: name.to_string(),
            cache_cols: None,
            cache_dims: None,
            cache_wbin: None,
        }
    }

    /// Store key of the latent weight parameter.
    fn w_fp_key(&self) -> String {
        format!("{}.w_fp", self.name)
    }
}

impl Layer for LatentBinConv2d {
    fn forward(&mut self, x: Value, train: bool) -> Value {
        let t = x.to_f32();
        let (n, c, h, w) = t.dims4();
        assert_eq!(c, self.c_in);
        let oh = (h + 2 * self.pad - self.k) / self.stride + 1;
        let ow = (w + 2 * self.pad - self.k) / self.stride + 1;
        let cols = t.im2col(self.k, self.stride, self.pad);
        let w_bin = binarize_weights(&self.w_fp, self.scale);
        let y = cols.matmul_bt(&w_bin).rows_to_nchw(n, self.c_out, oh, ow);
        if train {
            self.cache_cols = Some(cols);
            self.cache_dims = Some((n, h, w, oh, ow));
            self.cache_wbin = Some(w_bin);
        }
        Value::F32(y)
    }

    fn backward(&mut self, z: Tensor, store: &mut ParamStore) -> Tensor {
        let (n, h, w, oh, ow) = self.cache_dims.expect("backward before forward");
        assert_eq!(z.shape, vec![n, self.c_out, oh, ow]);
        let z_rows = z.nchw_to_rows();
        let cols = self.cache_cols.as_ref().unwrap();
        // STE to the latent weights: dL/dw_fp = dL/dw_bin · 1{|w_fp| ≤ 1}
        let mut g_wbin = z_rows.matmul_at(cols);
        for i in 0..g_wbin.len() {
            if self.w_fp.data[i].abs() > 1.0 {
                g_wbin.data[i] = 0.0;
            }
        }
        store.accumulate(&self.w_fp_key(), &g_wbin);
        let w_bin = self.cache_wbin.as_ref().unwrap();
        z_rows.matmul(w_bin).col2im(n, self.c_in, h, w, self.k, self.stride, self.pad)
    }

    fn params(&mut self) -> Vec<ParamRef<'_>> {
        let name = self.w_fp_key();
        vec![ParamRef::Real { name, w: &mut self.w_fp }]
    }

    fn name(&self) -> String {
        self.name.clone()
    }
}

/// Linear layer with latent FP weights binarized in the forward.
pub struct LatentBinLinear {
    pub n_in: usize,
    pub n_out: usize,
    pub w_fp: Tensor,
    pub scale: bool,
    name: String,
    cache_x: Option<Tensor>,
    cache_wbin: Option<Tensor>,
}

impl LatentBinLinear {
    pub fn new(name: &str, n_in: usize, n_out: usize, scale: bool, rng: &mut Rng) -> Self {
        LatentBinLinear {
            n_in,
            n_out,
            w_fp: Tensor::randn(&[n_out, n_in], 0.3, rng),
            scale,
            name: name.to_string(),
            cache_x: None,
            cache_wbin: None,
        }
    }

    /// Store key of the latent weight parameter.
    fn w_fp_key(&self) -> String {
        format!("{}.w_fp", self.name)
    }
}

impl Layer for LatentBinLinear {
    fn forward(&mut self, x: Value, train: bool) -> Value {
        let t = x.to_f32();
        let flat = t.view(&[t.shape[0], self.n_in]);
        let w_bin = binarize_weights(&self.w_fp, self.scale);
        let y = flat.matmul_bt(&w_bin);
        if train {
            self.cache_x = Some(flat);
            self.cache_wbin = Some(w_bin);
        }
        Value::F32(y)
    }

    fn backward(&mut self, z: Tensor, store: &mut ParamStore) -> Tensor {
        let x = self.cache_x.as_ref().expect("backward before forward");
        let mut g_wbin = z.matmul_at(x);
        for i in 0..g_wbin.len() {
            if self.w_fp.data[i].abs() > 1.0 {
                g_wbin.data[i] = 0.0;
            }
        }
        store.accumulate(&self.w_fp_key(), &g_wbin);
        z.matmul(self.cache_wbin.as_ref().unwrap())
    }

    fn params(&mut self) -> Vec<ParamRef<'_>> {
        let name = self.w_fp_key();
        vec![ParamRef::Real { name, w: &mut self.w_fp }]
    }

    fn name(&self) -> String {
        self.name.clone()
    }
}

/// VGG-SMALL in a BNN-baseline flavour (first conv and last FC stay FP,
/// the standard BNN convention — same as the paper's setup for B⊕LD).
pub fn bnn_vgg_small(
    kind: BnnKind,
    cfg: &crate::models::VggConfig,
    rng: &mut Rng,
) -> crate::nn::Sequential {
    use crate::nn::{BatchNorm2d, Conv2d, Flatten, Linear, MaxPool2d, Sequential};
    let [c1, c2, c3] = cfg.channels();
    let scale = kind.scale_weights();
    let binact = kind.binarize_activations();
    let mut net = Sequential::new(&format!("vgg_small_{kind:?}"));

    let act = |net: &mut Sequential, name: &str| {
        if binact {
            net.push(Box::new(SignSTE::new(name)));
        } else {
            net.push(Box::new(crate::nn::ReLU::new(name)));
        }
    };

    net.push(Box::new(Conv2d::new("conv1a", cfg.in_channels, c1, 3, 1, 1, rng)));
    net.push(Box::new(BatchNorm2d::new("bn1a", c1)));
    act(&mut net, "act1a");
    net.push(Box::new(LatentBinConv2d::new("conv1b", c1, c1, 3, 1, 1, scale, rng)));
    net.push(Box::new(MaxPool2d::new("mp1", 2)));
    net.push(Box::new(BatchNorm2d::new("bn1b", c1)));
    act(&mut net, "act1b");

    net.push(Box::new(LatentBinConv2d::new("conv2a", c1, c2, 3, 1, 1, scale, rng)));
    net.push(Box::new(BatchNorm2d::new("bn2a", c2)));
    act(&mut net, "act2a");
    net.push(Box::new(LatentBinConv2d::new("conv2b", c2, c2, 3, 1, 1, scale, rng)));
    net.push(Box::new(MaxPool2d::new("mp2", 2)));
    net.push(Box::new(BatchNorm2d::new("bn2b", c2)));
    act(&mut net, "act2b");

    net.push(Box::new(LatentBinConv2d::new("conv3a", c2, c3, 3, 1, 1, scale, rng)));
    net.push(Box::new(BatchNorm2d::new("bn3a", c3)));
    act(&mut net, "act3a");
    net.push(Box::new(LatentBinConv2d::new("conv3b", c3, c3, 3, 1, 1, scale, rng)));
    net.push(Box::new(MaxPool2d::new("mp3", 2)));
    net.push(Box::new(BatchNorm2d::new("bn3b", c3)));
    act(&mut net, "act3b");

    net.push(Box::new(Flatten::new("flat")));
    let spatial = cfg.hw / 8;
    net.push(Box::new(Linear::new("head", c3 * spatial * spatial, cfg.classes, rng)));
    net
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::VggConfig;
    use crate::nn::Layer;

    #[test]
    fn weight_binarization_is_pm_alpha() {
        let mut rng = Rng::new(1);
        let w = Tensor::randn(&[3, 8], 0.5, &mut rng);
        let plain = binarize_weights(&w, false);
        assert!(plain.data.iter().all(|&v| v == 1.0 || v == -1.0));
        let scaled = binarize_weights(&w, true);
        for j in 0..3 {
            let alpha = w.data[j * 8..(j + 1) * 8].iter().map(|v| v.abs()).sum::<f32>() / 8.0;
            for i in 0..8 {
                assert!((scaled.at2(j, i).abs() - alpha).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn ste_clips_gradient() {
        let mut s = SignSTE::new("s");
        let x = Tensor::from_vec(&[1, 3], vec![0.5, -2.0, 0.9]);
        let _ = s.forward(Value::F32(x), true);
        let g = s.backward(Tensor::full(&[1, 3], 1.0), &mut ParamStore::new());
        assert_eq!(g.data, vec![1.0, 0.0, 1.0]);
    }

    #[test]
    fn latent_linear_ste_masks_saturated_weights() {
        let mut rng = Rng::new(2);
        let mut l = LatentBinLinear::new("l", 4, 2, false, &mut rng);
        l.w_fp.data[0] = 3.0; // saturated: no gradient
        l.w_fp.data[1] = 0.5;
        let x = Tensor::full(&[1, 4], 1.0);
        let mut store = ParamStore::new();
        let _ = l.forward(Value::F32(x), true);
        let _ = l.backward(Tensor::full(&[1, 2], 1.0), &mut store);
        let gw = store.grad("l.w_fp").unwrap();
        assert_eq!(gw.data[0], 0.0);
        assert_eq!(gw.data[1], 1.0);
    }

    #[test]
    fn all_kinds_build_and_run() {
        let mut rng = Rng::new(3);
        let cfg = VggConfig { hw: 16, width_mult: 0.0625, ..Default::default() };
        for kind in [BnnKind::BinaryConnect, BnnKind::BinaryNet, BnnKind::XnorNet] {
            let mut net = bnn_vgg_small(kind, &cfg, &mut rng);
            let x = Tensor::randn(&[2, 3, 16, 16], 1.0, &mut rng);
            let y = net.forward(Value::F32(x), true).expect_f32("t");
            assert_eq!(y.shape, vec![2, 10], "{kind:?}");
            let g = net.backward(Tensor::full(&[2, 10], 0.1), &mut ParamStore::new());
            assert_eq!(g.shape, vec![2, 3, 16, 16]);
        }
    }

    #[test]
    fn bitwidths_match_table1() {
        assert_eq!(BnnKind::BinaryConnect.bitwidths(), (1, 32));
        assert_eq!(BnnKind::BinaryNet.bitwidths(), (1, 1));
        assert_eq!(BnnKind::XnorNet.bitwidths(), (1, 1));
    }
}
