//! # B⊕LD: Boolean Logic Deep Learning — full-system reproduction
//!
//! Reproduction of *B⊕LD: Boolean Logic Deep Learning* (Nguyen et al.,
//! NeurIPS 2024): deep models with **native Boolean weights and
//! activations**, trained directly in the Boolean domain by the *Boolean
//! variation* calculus (§3.2) and the Boolean optimizer (§3.3) — no
//! gradient descent, no FP latent weights.
//!
//! See `DESIGN.md` for the system inventory and the per-experiment index,
//! and `EXPERIMENTS.md` for paper-vs-measured results and the bench/perf
//! log.
//!
//! Layer map (three-layer rust+JAX architecture):
//! * L3 — this crate: coordinator, native bit-packed training engine,
//!   energy model, baselines, data pipeline, bench/report harness, and
//!   the forward-only packed serving stack ([`runtime`]: engine + batch
//!   server, `bold serve-native`);
//! * L2 — `python/compile/model.py`: jax Boolean train-step graphs, AOT
//!   lowered to `artifacts/*.hlo.txt` (loaded by [`runtime`] when built
//!   with the off-by-default `xla-runtime` feature);
//! * L1 — `python/compile/kernels/`: Pallas xnor-popcount kernels.
//!
//! Default builds have **zero external dependencies**: the XLA/PJRT path
//! is feature-gated so `cargo build --release` works fully offline and the
//! serving hot path is the paper's own XOR+POPCNT kernel
//! ([`tensor::BitMatrix::xnor_threshold`]).

#![deny(rustdoc::broken_intra_doc_links)]
// Clippy runs in CI with `-D warnings` (see .github/workflows/ci.yml).
// Three style lints are allowed crate-wide, with cause:
// - `should_implement_trait`: `Tensor::add`/`sub` are borrowing value
//   helpers (`&self, &T -> T`), deliberately NOT `std::ops` overloads —
//   operator sugar on a heap tensor type invites accidental clones.
// - `needless_range_loop`: the numeric kernels index several buffers per
//   iteration with one computed index; rewriting as iterator chains
//   obscures the (bounds-check-free) hot loops.
// - `too_many_arguments`: conv/geometry constructors mirror the paper's
//   explicit parameter lists (c_in, c_out, k, stride, pad, …).
#![allow(
    clippy::should_implement_trait,
    clippy::needless_range_loop,
    clippy::too_many_arguments
)]

pub mod baselines;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod energy;
pub mod logic;
pub mod models;
pub mod nn;
pub mod optim;
pub mod report;
pub mod runtime;
pub mod tensor;
pub mod testing;
pub mod util;
